"""Differential oracle: paged + speculative decode vs contiguous greedy.

The exactness contract of `models.transformer.paged_decode_step`: it
reproduces `decode_step`'s per-token computation graph exactly — the
page-table gather/scatter is pure data movement — so for ANY spec_k the
scheduler's emitted token sequences must be IDENTICAL (not just close)
to a contiguous single-token greedy decode loop.  Verified here for a
dense GQA family (starcoder2) and an MLA family (minicpm3), including
streams physically sharing prefix pages, park/resume interleavings,
spill/refill through the pager, and kill/restore.

The comparison target is a direct batch-1 `decode_step` loop — the
canonical greedy semantics.  (Note: `jax.vmap` over batch-1 decode_step
— the contiguous scheduler's step — produces different bf16 rounding
than direct `decode_step` for MLA near argmax ties; the paged step
matches the direct loop bit-for-bit on both families, which is the
stronger anchor.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.session import ResilienceSession
from repro.cluster.topology import VirtualCluster
from repro.configs import get_config
from repro.core.scr import Strategy
from repro.models.registry import get_model
from repro.serve.kvpage import KVPager
from repro.serve.prefix import PrefixCache
from repro.serve.scheduler import PagedServeScheduler
from repro.serve.spec import NGramProposer


@pytest.fixture(scope="module", params=["starcoder2-7b", "minicpm3-4b"],
                ids=["gqa", "mla"])
def arch(request):
    # this module recompiles many decode variants; shed the XLA state
    # accumulated by the rest of the suite first (long single-process
    # runs have segfaulted in CPU XLA under compile-cache churn)
    jax.clear_caches()
    cfg = get_config(request.param).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def greedy_reference(cfg, model, params, prompt, max_new, max_len):
    """Direct batch-1 decode_step loop: canonical contiguous greedy."""
    cache = model.init_cache(cfg, 1, max_len)
    toks = list(prompt)
    pos, out = 0, []
    while len(out) < max_new and pos < max_len:
        tok = toks[pos]
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([tok], jnp.int32), jnp.int32(pos), cfg)
        pos += 1
        if pos >= len(prompt):
            nxt = int(np.asarray(logits.argmax(axis=-1))[0])
            toks.append(nxt)
            out.append(nxt)
    return out


def check_all(sched, sids, prompts, refs):
    for sid, prompt, want in zip(sids, prompts, refs):
        got = sched.output(sid)
        assert got == want, (
            f"stream {sid} (prompt {list(prompt)}): {got} != greedy {want}")


MAX_LEN, MAX_NEW, PT = 24, 6, 4


@pytest.mark.parametrize("spec_k", [0, 3])
def test_paged_decode_is_exactly_greedy(arch, spec_k):
    """Multi-stream paged decode (with and without speculation) emits
    token sequences identical to the contiguous greedy loop."""
    cfg, model, params = arch
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(rng.integers(2, 10)))
               for _ in range(5)]
    refs = [greedy_reference(cfg, model, params, list(p), MAX_NEW, MAX_LEN)
            for p in prompts]
    sched = PagedServeScheduler(cfg, model, params, slots=2, max_len=MAX_LEN,
                                quantum=3, page_tokens=PT, spec_k=spec_k)
    sids = [sched.submit(p, max_new=MAX_NEW) for p in prompts]
    sched.run()
    check_all(sched, sids, prompts, refs)
    assert sched.stats["parked"] > 0, "oversubscription must exercise parking"
    # park/resume never moved KV bytes: pages stayed pool-resident
    assert sched.stats["kv_resume_bytes_moved"] == 0
    if spec_k:
        assert sched.stats["spec_proposed"] > 0
    assert sched.pool.used_pages() == 0, "finished streams must free pages"


def test_speculation_accepts_on_repetitive_prompts(arch):
    """Greedy loops are where n-gram proposals win: acceptance must be
    strictly positive AND the output still exactly greedy."""
    cfg, model, params = arch
    prompt = [7, 8, 9] * 3          # periodic: lookup proposals hit
    want = greedy_reference(cfg, model, params, prompt, 10, 32)
    sched = PagedServeScheduler(cfg, model, params, slots=1, max_len=32,
                                page_tokens=PT, spec_k=2)
    sid = sched.submit(prompt, max_new=10)
    steps = sched.run()
    assert sched.output(sid) == want
    assert sched.stats["spec_accepted"] > 0, "no proposal ever accepted"
    # accepted speculation means fewer steps than tokens emitted
    assert steps < len(want) + 2


def test_shared_prefix_pages_and_spec(arch):
    """Streams sharing a prompt prefix decode through the SAME physical
    pool pages — outputs must still match per-stream greedy exactly."""
    cfg, model, params = arch
    rng = np.random.default_rng(23)
    shared = list(rng.integers(0, cfg.vocab_size, size=9))
    prompts = [shared + list(rng.integers(0, cfg.vocab_size, size=int(n)))
               for n in rng.integers(1, 5, size=5)]
    refs = [greedy_reference(cfg, model, params, p, MAX_NEW, MAX_LEN)
            for p in prompts]
    pager = KVPager.for_capacity(fast_bytes=10**8, page_bytes=4096)
    prefix = PrefixCache.for_model(pager.stack, cfg, model, MAX_LEN,
                                   page_tokens=PT)
    sched = PagedServeScheduler(cfg, model, params, slots=3, max_len=MAX_LEN,
                                page_tokens=PT, spec_k=2, pager=pager,
                                prefix=prefix)
    sids = [sched.submit(p, max_new=MAX_NEW) for p in prompts]
    sched.run()
    check_all(sched, sids, prompts, refs)
    assert sched.stats["prefix_pool_shared"] > 0, \
        "later streams must reference the resident prefix pages"
    assert sched.stats["prefill_tokens_saved"] > 0
    # only the digest-bound prefix pages stay resident after finish
    assert sched.pool.used_pages() == len(sched.pool.resident_digests())
    sched.close()


def test_spill_refill_under_pool_pressure(arch):
    """A pool too small for all resident streams forces page-granular
    spill/refill through the pager — the ONLY path that may move KV
    bytes — and outputs still match greedy exactly."""
    cfg, model, params = arch
    rng = np.random.default_rng(31)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(rng.integers(2, 8)))
               for _ in range(6)]
    refs = [greedy_reference(cfg, model, params, list(p), MAX_NEW, MAX_LEN)
            for p in prompts]
    pager = KVPager.for_capacity(fast_bytes=10**8, page_bytes=4096)
    pages_per_lane = MAX_LEN // PT
    sched = PagedServeScheduler(cfg, model, params, slots=2, max_len=MAX_LEN,
                                quantum=2, page_tokens=PT, spec_k=1,
                                pager=pager, pool_pages=3 * pages_per_lane)
    sids = [sched.submit(p, max_new=MAX_NEW) for p in prompts]
    sched.run()
    check_all(sched, sids, prompts, refs)
    assert sched.stats["spilled"] > 0 and sched.stats["refilled"] > 0
    assert sched.stats["kv_resume_bytes_moved"] > 0
    assert (sched.stats["kv_resume_bytes_moved"]
            == sched.pager.stats()["kv_resume_bytes_moved"])
    assert sched.pool.used_pages() == 0
    sched.close()


def test_kill_restore_is_byte_identical(arch, tmp_path):
    """Kill mid-decode with speculation live: the restored pool buffer is
    byte-identical, and the continuation equals the uninterrupted run."""
    cfg, model, params = arch
    rng = np.random.default_rng(43)
    shared = list(rng.integers(0, cfg.vocab_size, size=6))
    prompts = [shared + list(rng.integers(0, cfg.vocab_size, size=3))
               for _ in range(4)]
    cluster = VirtualCluster(4, 0, root=tmp_path)

    def build(session, pager, prefix):
        return PagedServeScheduler(
            cfg, model, params, slots=2, max_len=MAX_LEN, quantum=2,
            page_tokens=PT, spec_k=2, pager=pager, prefix=prefix,
            session=session)

    with ResilienceSession.for_cluster(cluster, strategy=Strategy.XOR,
                                       procs_per_node=2) as session:
        pager1 = KVPager.for_capacity(fast_bytes=10**8, page_bytes=4096)
        prefix1 = PrefixCache.for_model(pager1.stack, cfg, model, MAX_LEN,
                                        page_tokens=PT)
        s1 = build(session, pager1, prefix1)
        for p in prompts:
            s1.submit(p, max_new=MAX_NEW)
        for _ in range(4):
            s1.step()
        s1.save()
        snap_tokens = {sid: list(s.tokens) for sid, s in s1.streams.items()}
        pool_before = s1.pool.snapshot()
        s1.run()    # ground truth: the uninterrupted continuation
        truth = {sid: s1.output(sid) for sid in s1.streams}

        # "fresh process": everything rebuilt from the checkpoint alone
        pager2 = KVPager.for_capacity(fast_bytes=10**8, page_bytes=4096)
        prefix2 = PrefixCache.for_model(pager2.stack, cfg, model, MAX_LEN,
                                        page_tokens=PT)
        s2 = build(session, pager2, prefix2)
        s2.restore()
        assert {sid: list(s.tokens)
                for sid, s in s2.streams.items()} == snap_tokens
        pool_after = s2.pool.snapshot()
        for name in pool_before:
            assert np.array_equal(pool_before[name], pool_after[name]), \
                f"pool leaf {name} not byte-identical after restore"
        s2.run()
        for sid in truth:
            assert s2.output(sid) == truth[sid], f"stream {sid} diverged"
        s1.close()
        s2.close()


def test_engine_paged_spec_matches_contiguous_engine(arch):
    """The ServeEngine lockstep surface: paged+speculative rows equal
    the contiguous engine's rows position for position."""
    from repro.serve.engine import ServeEngine
    cfg, model, params = arch
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=(2, 5)).astype(np.int32)
    ref = ServeEngine(cfg, model, params, batch=2, max_len=MAX_LEN)
    first_ref = np.asarray(ref.prefill(prompt))
    rows_ref = ref.decode(5)
    ref.close()
    eng = ServeEngine(cfg, model, params, batch=2, max_len=MAX_LEN,
                      paged=True, spec_k=2, page_tokens=PT)
    first = np.asarray(eng.prefill(prompt))
    rows = eng.decode(5)
    eng.close()
    np.testing.assert_array_equal(first, first_ref)
    assert len(rows) == len(rows_ref)
    for i, (a, b) in enumerate(zip(rows, rows_ref)):
        np.testing.assert_array_equal(a, b, err_msg=f"row {i}")


def test_ngram_proposer_is_deterministic_and_bounded():
    p = NGramProposer(max_n=3, window=64)
    hist = [1, 2, 3, 1, 2, 3, 1, 2]
    a = p.propose(hist, 4)
    assert a == p.propose(list(hist), 4)       # pure function of history
    assert len(a) == 4
    assert a[0] == 3                           # continues the loop
    assert p.propose([], 3) == [0, 0, 0]
    assert p.propose([5], 2) == [5, 5]         # pad by repetition
