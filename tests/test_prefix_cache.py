"""PrefixCache: radix matching, refcount eviction, scheduler reuse, and
kill/restore with shared pages live."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.session import ResilienceSession
from repro.cluster.topology import VirtualCluster
from repro.configs import get_config
from repro.core.scr import Strategy
from repro.io.serialization import serialize_state
from repro.memory.stack import TierStack
from repro.memory.tiers import MemoryTier, TierKind, TierSpec
from repro.models.registry import get_model
from repro.serve.kvpage import KVPager
from repro.serve.prefix import LaneLayout, PrefixCache, prefix_page_key
from repro.serve.scheduler import ServeScheduler, StreamState


# ---------------------------------------------------------------------- #
# standalone trie over a toy attention-shaped lane
# ---------------------------------------------------------------------- #


def toy_layout(max_len=16):
    """A two-leaf attention-style lane: every leaf has a kv_seq axis."""
    template = {
        "k": np.zeros((2, 1, max_len, 4), np.float32),
        "v": np.zeros((2, 1, max_len, 4), np.float32),
    }
    axes = {
        "k": ("layers", "batch", "kv_seq", None),
        "v": ("layers", "batch", "kv_seq", None),
    }
    return LaneLayout(template, axes)


def toy_stack(capacity=1 << 20):
    tier = MemoryTier(TierSpec(TierKind.DRAM, capacity, 1e9, 1e9, 1e-6))
    return TierStack([("fast", tier),
                      ("global", MemoryTier(
                          TierSpec(TierKind.GLOBAL, 1 << 30, 1e9, 1e9, 1e-4)))])


def filled_lane(layout, upto, base=1.0):
    lane = layout.zero_lane()
    lane["k"][:, :, :upto] = base
    lane["v"][:, :, :upto] = base * 2
    return lane


def test_match_and_fetch_roundtrip():
    layout = toy_layout()
    cache = PrefixCache(toy_stack(), layout, page_tokens=4)
    tokens = list(range(10))            # 2 full pages + 2 leftover tokens
    lane = filled_lane(layout, 10)
    path = cache.extend(tokens[:8], 8, lane)
    assert len(path) == 2 and path[-1].end == 8
    covered, hit = cache.match(tokens)
    assert covered == 8 and len(hit) == 2
    fresh = layout.zero_lane()
    got = cache.fetch_into(hit, fresh)
    assert got == 8
    assert np.array_equal(fresh["k"][:, :, :8], lane["k"][:, :, :8])
    assert np.array_equal(fresh["v"][:, :, :8], lane["v"][:, :, :8])
    assert not fresh["k"][:, :, 8:].any()   # beyond the prefix untouched

    # a diverging prompt shares only the first page
    other = tokens[:4] + [99, 98, 97, 96]
    covered2, hit2 = cache.match(other)
    assert covered2 == 4 and len(hit2) == 1
    assert hit2[0].digest == hit[0].digest  # literally the same node


def test_content_addressing_dedups_across_inserters():
    layout = toy_layout()
    cache = PrefixCache(toy_stack(), layout, page_tokens=4)
    lane = filled_lane(layout, 8)
    cache.extend(list(range(8)), 8, lane)
    n = len(cache)
    cache.extend(list(range(8)), 8, lane)   # same prefix again: no new nodes
    assert len(cache) == n
    assert cache.stats["pages_inserted"] == n


def test_refcounted_shared_page_survives_stream_finish():
    """THE eviction contract: a page shared by two streams must survive
    one of them finishing — only fully-unreferenced leaves are evictable,
    even when the cache is over its byte budget."""
    layout = toy_layout()
    cache = PrefixCache(toy_stack(), layout, page_tokens=4,
                        capacity_bytes=1)   # everything is over budget
    lane = filled_lane(layout, 8)
    # stream A inserts and holds its path atomically (sid= acquires
    # before the eviction sweep — an inserter's pages can't vanish)
    path = cache.extend(list(range(8)), 8, lane, sid=101)
    assert len(cache) == 2
    cache.acquire(202, path)                # stream B shares the pages
    cache.release_stream(101)               # A finishes
    cache._maybe_evict()
    assert len(cache) == 2, "shared pages evicted while stream B is live"
    assert cache.stack.exists(prefix_page_key(path[0].digest))
    cache.release_stream(202)               # B finishes: now evictable
    cache._maybe_evict()
    assert len(cache) == 0
    assert not cache.stack.exists(prefix_page_key(path[0].digest))


def test_eviction_is_leaf_first_and_lru():
    layout = toy_layout()
    stack = toy_stack()
    cache = PrefixCache(stack, layout, page_tokens=4, capacity_bytes=None)
    lane = filled_lane(layout, 12)
    cache.extend(list(range(12)), 12, lane)     # chain of 3 nodes
    assert len(cache) == 3
    # shrink the budget to one node: only leaves may go, so the chain
    # peels from the deepest node upward
    cache.capacity_bytes = cache.stats["bytes_cached"] // 3
    cache._maybe_evict()
    assert len(cache) == 1
    covered, hit = cache.match(list(range(12)))
    assert covered == 4 and hit[0].end == 4, "interior node evicted first"


def test_release_stream_is_idempotent():
    layout = toy_layout()
    cache = PrefixCache(toy_stack(), layout, page_tokens=4)
    path = cache.extend(list(range(4)), 4, filled_lane(layout, 4))
    cache.acquire(7, path)
    cache.release_stream(7)
    cache.release_stream(7)
    assert cache.node(path[0].digest).refs == 0


# ---------------------------------------------------------------------- #
# scheduler integration (real model, slice + snapshot modes)
# ---------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def served_model():
    cfg = get_config("phi3-mini-3.8b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def make_prefix_scheduler(cfg, model, params, slots, max_len, session=None,
                          quantum=3, fast_lanes=3, page_tokens=4,
                          page_bytes=None):
    lane_bytes = serialize_state(
        jax.device_get(model.init_cache(cfg, 1, max_len))).nbytes
    pager = KVPager.for_capacity(fast_bytes=fast_lanes * lane_bytes,
                                 page_bytes=page_bytes
                                 or max(1024, lane_bytes // 4))
    prefix = PrefixCache.for_model(pager.stack, cfg, model, max_len,
                                   page_tokens=page_tokens)
    return ServeScheduler(cfg, model, params, slots=slots, max_len=max_len,
                          pager=pager, session=session, quantum=quantum,
                          prefix=prefix)


def reference_decode(cfg, model, params, prompt, max_new, max_len):
    cache = model.init_cache(cfg, 1, max_len)
    toks = list(prompt)
    pos = 0
    out = []
    while len(out) < max_new and pos < max_len:
        tok = toks[pos]
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([tok], jnp.int32), jnp.int32(pos), cfg)
        pos += 1
        if pos >= len(prompt):
            nxt = int(np.asarray(logits.argmax(axis=-1))[0])
            toks.append(nxt)
            out.append(nxt)
    return out


def shared_prompts(cfg, n, shared_len=9, seed=3):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, size=shared_len).tolist()
    return [shared + rng.integers(0, cfg.vocab_size,
                                  size=int(rng.integers(2, 5))).tolist()
            for _ in range(n)]


def test_shared_prefix_streams_match_reference_and_save_prefill(served_model):
    """Streams sharing a 9-token prefix: later joiners fetch the cached
    pages (prefill_tokens_saved > 0) and every output still equals an
    independent batch-1 decode — the cache is numerically transparent."""
    cfg, model, params = served_model
    max_len, max_new = 24, 4
    sched = make_prefix_scheduler(cfg, model, params, slots=2, max_len=max_len)
    prompts = shared_prompts(cfg, 6)
    sids = [sched.submit(p, max_new=max_new) for p in prompts]
    sched.run()
    assert sched.stats["prefix_hits"] >= 5          # every joiner after #0
    assert sched.stats["prefill_tokens_saved"] > 0
    st = sched.pager.stats()
    assert st["hits_hbm"] + st["hits_dram"] > 0     # pages read through tiers
    for sid, p in zip(sids, prompts):
        want = reference_decode(cfg, model, params, p, max_new, max_len)
        assert sched.output(sid) == want, f"stream {sid} diverged"
    sched.close()


def test_snapshot_mode_for_recurrent_family():
    """rwkv has no kv_seq axis: the prefix cache falls back to boundary
    state snapshots, still saving prefill work and staying exact."""
    cfg = get_config("rwkv6-3b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    max_len, max_new = 20, 3
    sched = make_prefix_scheduler(cfg, model, params, slots=2, max_len=max_len)
    assert sched.prefix.mode == "snapshot"
    prompts = shared_prompts(cfg, 4, shared_len=9, seed=5)
    sids = [sched.submit(p, max_new=max_new) for p in prompts]
    sched.run()
    assert sched.stats["prefill_tokens_saved"] > 0
    for sid, p in zip(sids, prompts):
        want = reference_decode(cfg, model, params, p, max_new, max_len)
        assert sched.output(sid) == want, f"stream {sid} diverged"
    sched.close()


def test_kill_restore_with_shared_pages_live(served_model, tmp_path):
    """Mid-decode kill while the prefix trie is populated and parked page
    tables reference the dedup'd pool; a FRESH scheduler restores trie,
    refcounts, and tables from the checkpoint alone and finishes every
    stream byte-identically."""
    cfg, model, params = served_model
    max_len, max_new, slots = 24, 4, 2
    prompts = shared_prompts(cfg, 8, seed=11)

    ref = make_prefix_scheduler(cfg, model, params, slots, max_len)
    for p in prompts:
        ref.submit(p, max_new=max_new)
    ref.run()
    want = {sid: ref.output(sid) for sid in ref.streams}
    ref.close()

    cluster = VirtualCluster(4, 0, root=tmp_path)
    with ResilienceSession.for_cluster(cluster, strategy=Strategy.XOR,
                                       procs_per_node=2) as session:
        s1 = make_prefix_scheduler(cfg, model, params, slots, max_len,
                                   session=session)
        for p in prompts:
            s1.submit(p, max_new=max_new)
        s1.run(max_steps=6)
        assert len(s1.prefix) > 0, "kill point must have shared pages live"
        assert StreamState.PARKED in {s.state for s in s1.streams.values()}
        refs_before = s1.prefix.stream_refs()
        nodes_before = len(s1.prefix)
        s1.save()
        saved_step = s1.step_count
        s1.close()

        s2 = make_prefix_scheduler(cfg, model, params, slots, max_len,
                                   session=session)
        got_step = s2.restore()
        assert got_step == saved_step
        assert len(s2.prefix) == nodes_before
        assert s2.prefix.stream_refs() == refs_before
        s2.run()
        assert {sid: s2.output(sid) for sid in s2.streams} == want
        s2.close()


def test_checkpoint_pages_are_deduped(served_model, tmp_path):
    """The checkpoint stores each unique parked page once: the summed
    table sizes exceed the stored page payloads whenever streams share
    content (zero tails at minimum)."""
    cfg, model, params = served_model
    max_len, slots = 24, 2
    prompts = shared_prompts(cfg, 6, seed=13)
    cluster = VirtualCluster(4, 0, root=tmp_path)
    with ResilienceSession.for_cluster(cluster, strategy=Strategy.XOR,
                                       procs_per_node=2) as session:
        # fine pages so identical byte ranges across lanes (the shared
        # prompt prefix, zero tails) actually coincide page-for-page
        s1 = make_prefix_scheduler(cfg, model, params, slots, max_len,
                                   session=session, page_bytes=256)
        for p in prompts:
            s1.submit(p, max_new=4)
        s1.run(max_steps=5)
        assert len(s1.pager.parked_sids()) >= 2
        s1.save()
        meta = session.checkpoint_meta(s1.step_count)["serve"]["pager"]
        logical = sum(nbytes for _, nbytes, _, _ in meta["tables"])
        stored = sum(meta["page_lens"])
        assert stored < logical, (
            f"checkpoint page set not dedup'd: stored {stored} >= "
            f"logical {logical}")
        assert s1.pager.pooled_bytes() < s1.pager.parked_bytes()
        s1.close()
