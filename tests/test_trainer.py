"""Fault-tolerant trainer: recovery equivalence, pipeline determinism."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.cluster.topology import NodeState, VirtualCluster
from repro.configs import get_config
from repro.core.nam import NAMDevice
from repro.core.scr import SCRManager, Strategy
from repro.data.pipeline import TokenPipeline
from repro.memory.tiers import MemoryHierarchy
from repro.models.registry import get_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import FailureEvent, Trainer


def make_trainer(tmp_path, strategy=Strategy.BUDDY, failure_schedule=None,
                 subdir="a", ckpt_every=4):
    cfg = get_config("phi3-mini-3.8b").reduced()
    model = get_model(cfg)
    cluster = VirtualCluster(4, 4, root=tmp_path / subdir)
    hierarchy = MemoryHierarchy(cluster)
    nam = NAMDevice(hierarchy.nam_tier) if strategy == Strategy.NAM_XOR else None
    scr = SCRManager(cluster, hierarchy, nam=nam, strategy=strategy,
                     procs_per_node=2)
    pipeline = TokenPipeline(cfg.vocab_size, global_batch=4, seq_len=32)
    return Trainer(cfg, model, pipeline, scr,
                   opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=4),
                   ckpt_every=ckpt_every, failure_schedule=failure_schedule)


def final_params(trainer, steps):
    trainer.run(steps)
    state, got_step = trainer.scr.restore(
        __import__("repro.train.step", fromlist=["init_train_state"])
        .init_train_state(jax.random.PRNGKey(0), trainer.cfg, trainer.model)
    )
    assert got_step == steps
    return state["params"]


def test_recovery_bitwise_equals_uninterrupted(tmp_path):
    """Failure + restore must reproduce the uninterrupted run exactly:
    deterministic data pipeline + deterministic step = bitwise equality."""
    clean = make_trainer(tmp_path, subdir="clean")
    p_clean = final_params(clean, 12)

    faulty = make_trainer(
        tmp_path, subdir="faulty",
        failure_schedule=[FailureEvent(step=10, rank=3)],
    )
    p_faulty = final_params(faulty, 12)
    assert faulty.report.recoveries == 1

    for a, b in zip(jax.tree_util.tree_leaves(p_clean),
                    jax.tree_util.tree_leaves(p_faulty)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_failure_before_first_checkpoint_restarts_clean(tmp_path):
    tr = make_trainer(tmp_path, failure_schedule=[FailureEvent(step=2, rank=1)],
                      ckpt_every=50)
    report = tr.run(6)
    assert report.recoveries == 1
    assert report.restarts_from_step == [0]
    assert report.steps_run >= 6


def test_multiple_failures(tmp_path):
    tr = make_trainer(
        tmp_path, strategy=Strategy.NAM_XOR,
        failure_schedule=[FailureEvent(step=5, rank=2),
                          FailureEvent(step=9, rank=6)],
    )
    report = tr.run(12)
    assert report.failures == 2 and report.recoveries == 2
    assert np.isfinite(report.losses[-1])


def test_recovery_budget_enforced(tmp_path):
    tr = make_trainer(tmp_path,
                      failure_schedule=[FailureEvent(step=s, rank=1)
                                        for s in range(1, 12)])
    with pytest.raises(RuntimeError):
        tr.run(12, max_recoveries=3)


def test_pipeline_checkpoint_roundtrip():
    p1 = TokenPipeline(1000, 4, 16, seed=7)
    for _ in range(5):
        b_ref = p1.next_batch()
    state = p1.state()
    next_ref = p1.next_batch()

    p2 = TokenPipeline(1000, 4, 16, seed=7)
    p2.load_state(state)
    np.testing.assert_array_equal(p2.next_batch()["tokens"], next_ref["tokens"])


def test_pipeline_is_pure_function_of_step():
    p = TokenPipeline(1000, 2, 8, seed=1)
    a = p.batch_at(3)["tokens"]
    b = p.batch_at(3)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, p.batch_at(4)["tokens"])


def test_pipeline_seed_mismatch_rejected():
    p = TokenPipeline(1000, 2, 8, seed=1)
    with pytest.raises(ValueError):
        p.load_state({"seed": 2, "step": 0})
