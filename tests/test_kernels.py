"""Per-kernel allclose sweeps: Pallas (interpret) + chunked jnp vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba2_ssd import mamba2_pallas
from repro.kernels.rwkv6_scan import wkv6_pallas
from repro.models.layers import decode_attention, flash_attention


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------- #
# flash attention
# ---------------------------------------------------------------------- #

FLASH_CASES = [
    # b, tq, tk, hq, hkv, d, causal
    (1, 16, 16, 2, 2, 8, True),
    (2, 32, 32, 4, 2, 16, True),
    (1, 24, 40, 4, 1, 8, True),      # GQA + decode-offset
    (1, 16, 16, 2, 2, 8, False),
    (2, 33, 33, 3, 3, 8, True),      # non-divisible tiles
    (1, 7, 29, 2, 1, 8, True),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_pallas_flash_vs_ref(case):
    b, tq, tk, hq, hkv, d, causal = case
    ks = keys(sum(case[:-1]), 3)
    q = jax.random.normal(ks[0], (b, tq, hq, d))
    k = jax.random.normal(ks[1], (b, tk, hkv, d))
    v = jax.random.normal(ks[2], (b, tk, hkv, d))
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=8, block_k=8,
                                 interpret=True)
    want = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-6, rtol=1e-5)


@pytest.mark.parametrize("case", FLASH_CASES)
def test_jnp_flash_vs_ref(case):
    b, tq, tk, hq, hkv, d, causal = case
    ks = keys(100 + sum(case[:-1]), 3)
    q = jax.random.normal(ks[0], (b, tq, hq, d))
    k = jax.random.normal(ks[1], (b, tk, hkv, d))
    v = jax.random.normal(ks[2], (b, tk, hkv, d))
    got = flash_attention(q, k, v, causal=causal, q_chunk=8, k_chunk=8)
    want = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-6, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    ks = keys(7, 3)
    q = jax.random.normal(ks[0], (2, 16, 4, 8)).astype(dtype)
    k = jax.random.normal(ks[1], (2, 16, 2, 8)).astype(dtype)
    v = jax.random.normal(ks[2], (2, 16, 2, 8)).astype(dtype)
    got = flash_attention_pallas(q, k, v, block_q=8, block_k=8, interpret=True)
    want = ref.mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32))
    tol = 3e-6 if dtype == jnp.float32 else 3e-2
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               atol=tol, rtol=tol)


def test_decode_attention_vs_ref():
    ks = keys(3, 3)
    q = jax.random.normal(ks[0], (2, 6, 8))
    kc = jax.random.normal(ks[1], (2, 20, 2, 8))
    vc = jax.random.normal(ks[2], (2, 20, 2, 8))
    lengths = jnp.array([5, 17])
    got = decode_attention(q, kc, vc, lengths)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=1e-5)


# ---------------------------------------------------------------------- #
# rwkv6
# ---------------------------------------------------------------------- #

WKV_CASES = [(1, 16, 2, 8), (2, 50, 3, 16), (1, 33, 2, 8), (1, 128, 1, 32)]


def wkv_inputs(case, seed=0):
    b, t, h, d = case
    ks = keys(seed + sum(case), 5)
    r = jax.random.normal(ks[0], (b, t, h, d)) * 0.5
    k = jax.random.normal(ks[1], (b, t, h, d)) * 0.5
    v = jax.random.normal(ks[2], (b, t, h, d))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, d))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (h, d)) * 0.1
    return r, k, v, w, u


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv6_chunked_vs_ref(case):
    r, k, v, w, u = wkv_inputs(case)
    got, gs = ops.wkv6_chunked(r, k, v, w, u, chunk=16, d_block=8)
    want, ws = ref.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), atol=5e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv6_pallas_vs_ref(case):
    r, k, v, w, u = wkv_inputs(case, seed=9)
    got, gs = wkv6_pallas(r, k, v, w, u, chunk=16, interpret=True)
    want, ws = ref.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), atol=5e-5,
                               rtol=1e-4)


def test_wkv6_decode_chain_matches_scan():
    case = (2, 20, 2, 8)
    r, k, v, w, u = wkv_inputs(case, seed=4)
    want, _ = ref.rwkv6_ref(r, k, v, w, u)
    state = jnp.zeros((2, 2, 8, 8))
    outs = []
    for i in range(20):
        y, state = ops.wkv6_decode_step(r[:, i], k[:, i], v[:, i], w[:, i], u, state)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)), np.asarray(want),
                               atol=5e-5, rtol=1e-4)


def test_wkv6_state_chaining():
    """Splitting a sequence across two chunked calls == one call."""
    case = (1, 32, 2, 8)
    r, k, v, w, u = wkv_inputs(case, seed=11)
    full, fs = ops.wkv6_chunked(r, k, v, w, u, chunk=8, d_block=8)
    h1, s1 = ops.wkv6_chunked(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u,
                              chunk=8, d_block=8)
    h2, s2 = ops.wkv6_chunked(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u,
                              state=s1, chunk=8, d_block=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=5e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(fs), atol=5e-5, rtol=1e-4)


# ---------------------------------------------------------------------- #
# mamba2 SSD
# ---------------------------------------------------------------------- #

SSD_CASES = [(1, 16, 2, 8, 8), (2, 50, 3, 8, 12), (1, 33, 2, 16, 8),
             (1, 100, 1, 32, 16)]


def ssd_inputs(case, seed=0):
    b, t, h, p, n = case
    ks = keys(seed + sum(case), 5)
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, t, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, t, n)) * 0.5
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("case", SSD_CASES)
def test_mamba2_chunked_vs_ref(case):
    x, dt, A, Bm, Cm = ssd_inputs(case)
    got, gs = ops.mamba2_chunked(x, dt, A, Bm, Cm, chunk=16)
    want, ws = ref.mamba2_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), atol=5e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("case", SSD_CASES)
def test_mamba2_pallas_vs_ref(case):
    x, dt, A, Bm, Cm = ssd_inputs(case, seed=5)
    got, gs = mamba2_pallas(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    want, ws = ref.mamba2_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws), atol=5e-5,
                               rtol=1e-4)


def test_mamba2_decode_chain_matches_scan():
    case = (2, 20, 2, 8, 8)
    x, dt, A, Bm, Cm = ssd_inputs(case, seed=2)
    want, _ = ref.mamba2_ref(x, dt, A, Bm, Cm)
    state = jnp.zeros((2, 2, 8, 8))
    outs = []
    for i in range(20):
        y, state = ops.mamba2_decode_step(x[:, i], dt[:, i], A, Bm[:, i],
                                          Cm[:, i], state)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)), np.asarray(want),
                               atol=5e-5, rtol=1e-4)


# ---------------------------------------------------------------------- #
# gradients flow through the chunked kernels (training path)
# ---------------------------------------------------------------------- #


@pytest.mark.slow
def test_wkv6_chunked_grads_finite():
    r, k, v, w, u = wkv_inputs((1, 16, 2, 8), seed=21)

    def loss(r, k, v, w, u):
        y, _ = ops.wkv6_chunked(r, k, v, w, u, chunk=8, d_block=8)
        return jnp.sum(y**2)

    grads = jax.grad(loss, argnums=(0, 1, 2, 3, 4))(r, k, v, w, u)
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()


@pytest.mark.slow
def test_mamba2_chunked_grads_finite():
    x, dt, A, Bm, Cm = ssd_inputs((1, 16, 2, 8, 8), seed=22)

    def loss(x, dt, A, Bm, Cm):
        y, _ = ops.mamba2_chunked(x, dt, A, Bm, Cm, chunk=8)
        return jnp.sum(y**2)

    grads = jax.grad(loss, argnums=(0, 1, 2, 3, 4))(x, dt, A, Bm, Cm)
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()
