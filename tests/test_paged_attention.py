"""Paged-attention decode kernel: Pallas (interpret) + jnp vs oracles.

The contract fig11 leans on: the page-table-indexed gather is numerically
a no-op — paged output == contiguous `decode_attention` == causal
`flash_attention_pallas` with a length-1 query, including when sequences
physically share prefix pages in the pool.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import (
    paged_attention,
    paged_attention_pallas,
    paginate_cache,
)
from repro.models.layers import decode_attention


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


PAGED_CASES = [
    # b, s, hq, hkv, d, page
    (1, 16, 2, 2, 8, 8),
    (2, 32, 4, 2, 16, 8),
    (3, 24, 4, 1, 8, 8),        # GQA group 4
    (2, 20, 2, 2, 8, 8),        # ragged: s not a page multiple
    (1, 8, 2, 2, 8, 4),
]


def make_case(case, seed=0):
    b, s, hq, hkv, d, page = case
    ks = keys(seed + sum(case), 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, s, hkv, d))
    vc = jax.random.normal(ks[2], (b, s, hkv, d))
    rng = np.random.default_rng(sum(case))
    lengths = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    return q, kc, vc, lengths


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_pallas_vs_contiguous(case):
    q, kc, vc, lengths = make_case(case)
    page = case[-1]
    k_pages, v_pages, table = paginate_cache(kc, vc, page)
    want = decode_attention(q, kc, vc, lengths)
    got = paged_attention_pallas(q, k_pages, v_pages, table, lengths,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-6, rtol=1e-5)


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_jnp_vs_contiguous(case):
    q, kc, vc, lengths = make_case(case, seed=7)
    page = case[-1]
    k_pages, v_pages, table = paginate_cache(kc, vc, page)
    want = decode_attention(q, kc, vc, lengths)
    got = paged_attention(q, k_pages, v_pages, table, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-6, rtol=1e-5)


def test_paged_matches_flash_length1_query():
    """Full-length rows: paged decode == flash attention with tq=1 (the
    causal frontier sits at the last key either way)."""
    case = (2, 32, 4, 2, 16, 8)
    q, kc, vc, _ = make_case(case, seed=3)
    k_pages, v_pages, table = paginate_cache(kc, vc, case[-1])
    full = jnp.full((case[0],), case[1], jnp.int32)
    want = flash_attention_pallas(q[:, None], kc, vc, causal=True,
                                  block_q=8, block_k=8, interpret=True)[:, 0]
    got = paged_attention_pallas(q, k_pages, v_pages, table, full,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-6, rtol=1e-5)


def test_paged_with_physically_shared_prefix_pages():
    """Several sequences point their leading table entries at the SAME
    pool pages (the prefix-cache layout): each lane must read the shared
    pages as its own prefix."""
    b, s, hq, hkv, d, page = 4, 32, 4, 2, 8, 8
    shared_pages = 2
    ks = keys(11, 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = np.array(jax.random.normal(ks[1], (b, s, hkv, d)))
    vc = np.array(jax.random.normal(ks[2], (b, s, hkv, d)))
    kc[:, :shared_pages * page] = kc[0:1, :shared_pages * page]
    vc[:, :shared_pages * page] = vc[0:1, :shared_pages * page]
    k_pages, v_pages, table = paginate_cache(jnp.asarray(kc), jnp.asarray(vc),
                                             page)
    tbl = np.asarray(table).copy()
    tbl[:, :shared_pages] = tbl[0, :shared_pages]   # one physical copy
    lengths = jnp.asarray([20, 25, 30, 32], jnp.int32)
    want = decode_attention(q, jnp.asarray(kc), jnp.asarray(vc), lengths)
    got = paged_attention_pallas(q, k_pages, v_pages, jnp.asarray(tbl),
                                 lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-6, rtol=1e-5)


def test_sentinel_table_entries_are_safe():
    """Unused table tail entries may be -1 (or any sentinel): they are
    clamped before the index map, so the masked-out block DMA can never
    address outside the pool."""
    case = (2, 24, 2, 2, 8, 8)
    q, kc, vc, _ = make_case(case, seed=9)
    k_pages, v_pages, table = paginate_cache(kc, vc, case[-1])
    lengths = jnp.asarray([8, 16], jnp.int32)   # last page(s) unused
    tbl = np.asarray(table).copy()
    tbl[0, 1:] = -1                             # sentinel past the length
    tbl[1, 2:] = 10**6
    want = decode_attention(q, kc, vc, lengths)
    got = paged_attention_pallas(q, k_pages, v_pages, jnp.asarray(tbl),
                                 lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-6, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_dtypes(dtype):
    case = (2, 16, 4, 2, 8, 8)
    q, kc, vc, lengths = make_case(case, seed=5)
    q, kc, vc = q.astype(dtype), kc.astype(dtype), vc.astype(dtype)
    k_pages, v_pages, table = paginate_cache(kc, vc, case[-1])
    got = paged_attention_pallas(q, k_pages, v_pages, table, lengths,
                                 interpret=True)
    want = decode_attention(q.astype(jnp.float32), kc.astype(jnp.float32),
                            vc.astype(jnp.float32), lengths)
    tol = 3e-6 if dtype == jnp.float32 else 3e-2
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------- #
# multi-token verification rows (speculative decode)
# ---------------------------------------------------------------------- #


def test_multitok_jnp_vs_per_token_decode():
    """(B, T) verification rows == T independent single-token calls at
    positions pos..pos+T-1 — the property speculative decode stands on."""
    from repro.kernels.paged_attention import paged_attention_multitok

    b, s, hq, hkv, d, page, t = 2, 32, 4, 2, 8, 8, 3
    ks = keys(21, 3)
    q = jax.random.normal(ks[0], (b, t, hq, d))
    kc = jax.random.normal(ks[1], (b, s, hkv, d))
    vc = jax.random.normal(ks[2], (b, s, hkv, d))
    k_pages, v_pages, table = paginate_cache(kc, vc, page)
    base = np.asarray([10, 17], np.int32)
    positions = jnp.asarray(base[:, None] + np.arange(t)[None], jnp.int32)
    got = paged_attention_multitok(q, k_pages, v_pages, table, positions)
    for i in range(t):
        want = decode_attention(q[:, i], kc, vc,
                                jnp.asarray(base + i + 1, jnp.int32))
        np.testing.assert_allclose(np.asarray(got[:, i]), np.asarray(want),
                                   atol=3e-6, rtol=1e-5)


def test_multitok_pallas_folds_rows_into_batch():
    """The Pallas multi-row path (fold (B,T) into the kernel batch axis)
    == the jnp multi-token oracle, including ragged per-row positions."""
    from repro.kernels.paged_attention import (
        paged_attention_multitok, paged_attention_pallas_multitok)

    b, s, hq, hkv, d, page, t = 3, 24, 4, 1, 8, 8, 4
    ks = keys(23, 3)
    q = jax.random.normal(ks[0], (b, t, hq, d))
    kc = jax.random.normal(ks[1], (b, s, hkv, d))
    vc = jax.random.normal(ks[2], (b, s, hkv, d))
    k_pages, v_pages, table = paginate_cache(kc, vc, page)
    base = np.asarray([3, 11, 19], np.int32)
    positions = jnp.asarray(base[:, None] + np.arange(t)[None], jnp.int32)
    want = paged_attention_multitok(q, k_pages, v_pages, table, positions)
    got = paged_attention_pallas_multitok(q, k_pages, v_pages, table,
                                          positions, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-6, rtol=1e-5)


def test_multitok_causal_within_the_candidate_window():
    """Candidate i must see keys up to pos+i and NOT the later
    candidates' keys: perturbing key pos+T-1 must not change row 0."""
    from repro.kernels.paged_attention import paged_attention_multitok

    b, s, hq, hkv, d, page, t = 1, 16, 2, 2, 8, 8, 3
    ks = keys(29, 3)
    q = jax.random.normal(ks[0], (b, t, hq, d))
    kc = np.array(jax.random.normal(ks[1], (b, s, hkv, d)))
    vc = np.array(jax.random.normal(ks[2], (b, s, hkv, d)))
    positions = jnp.asarray([[4, 5, 6]], jnp.int32)
    k_pages, v_pages, table = paginate_cache(jnp.asarray(kc),
                                             jnp.asarray(vc), page)
    base_out = paged_attention_multitok(q, k_pages, v_pages, table, positions)
    kc[0, 6] += 100.0
    vc[0, 6] -= 100.0
    k_pages, v_pages, table = paginate_cache(jnp.asarray(kc),
                                             jnp.asarray(vc), page)
    pert_out = paged_attention_multitok(q, k_pages, v_pages, table, positions)
    # rows 0 and 1 attend only keys <= 4 and 5: unchanged
    np.testing.assert_array_equal(np.asarray(base_out[:, :2]),
                                  np.asarray(pert_out[:, :2]))
    # row 2 attends key 6: must change
    assert not np.allclose(np.asarray(base_out[:, 2]),
                           np.asarray(pert_out[:, 2]))
