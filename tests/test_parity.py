"""XOR parity codes: RAID-5 rotation + NAM parity, host and device paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips cleanly if absent

from repro.core import parity
from repro.io.serialization import partition_blob
from repro.kernels.ref import xor_reduce_ref
from repro.kernels.xor_parity import xor_reduce_pallas


@settings(max_examples=25, deadline=None)
@given(
    nbytes=st.integers(min_value=4, max_value=4096),
    group=st.integers(min_value=2, max_value=9),
    failed=st.integers(min_value=0, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_raid5_reconstructs_any_single_failure(nbytes, group, failed, seed):
    failed = failed % group
    data = np.random.default_rng(seed).bytes(nbytes)
    frags = partition_blob(data, group)
    blocks = parity.encode_xor_group(frags)
    surv_f = {i: frags[i] for i in range(group) if i != failed}
    surv_p = {i: blocks[i] for i in range(group) if i != failed}
    rec = parity.reconstruct_xor_group(failed, surv_f, surv_p, group, len(frags[0]))
    assert rec == frags[failed]


def test_raid5_storage_overhead():
    """Parity per rank is |F|/(N-1), not |F| (the paper's XOR argument)."""
    frags = partition_blob(np.random.default_rng(0).bytes(64_000), 8)
    blocks = parity.encode_xor_group(frags)
    assert len(blocks[0]) <= len(frags[0]) // (8 - 1) + 4


def test_raid5_requires_all_survivors():
    frags = partition_blob(b"x" * 1024, 4)
    blocks = parity.encode_xor_group(frags)
    with pytest.raises(RuntimeError):
        parity.reconstruct_xor_group(
            0, {1: frags[1]}, {1: blocks[1]}, 4, len(frags[0])
        )


@settings(max_examples=25, deadline=None)
@given(
    group=st.integers(min_value=2, max_value=8),
    failed=st.integers(min_value=0, max_value=7),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_nam_parity_reconstructs(group, failed, seed):
    failed = failed % group
    frags = partition_blob(np.random.default_rng(seed).bytes(2048), group)
    par = parity.encode_nam_parity(frags)
    surv = {i: frags[i] for i in range(group) if i != failed}
    assert parity.reconstruct_from_nam(failed, surv, par, group) == frags[failed]


def test_xor_bytes_involution():
    a = np.random.default_rng(2).bytes(1000)
    b = np.random.default_rng(3).bytes(1000)
    assert parity.xor_bytes([parity.xor_bytes([a, b]), b]) == a


@pytest.mark.parametrize("r,m", [(2, 1), (3, 7), (4, 64), (8, 300)])
def test_pallas_xor_matches_ref(r, m):
    rng = np.random.default_rng(r * 100 + m)
    x = jnp.asarray(rng.integers(-(2**31), 2**31, size=(r, m, 128), dtype=np.int32))
    got = xor_reduce_pallas(x, block_rows=64, interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(xor_reduce_ref(x)))


def test_pack_unpack_words():
    frags = [np.random.default_rng(i).bytes(1000) for i in range(3)]
    stacked = parity.pack_words(frags)
    assert stacked.shape[0] == 3 and stacked.shape[2] == 128
    out = parity.unpack_words(parity.xor_reduce(stacked, use_pallas=False), 1000)
    assert out == parity.xor_bytes(frags)
