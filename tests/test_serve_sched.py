"""Serving subsystem: hit-rate promotion, KV paging, continuous batching,
multi-stream kill/restore, and the failure-history checkpoint policy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.policy import FailureHistoryPolicy, PolicyContext
from repro.api.session import ResilienceSession
from repro.cluster.topology import VirtualCluster
from repro.configs import get_config
from repro.core.scr import Strategy
from repro.io.beeond import CacheFS
from repro.io.serialization import serialize_state
from repro.memory.stack import HitRatePromotion, TierStack
from repro.memory.tiers import CapacityError, MemoryTier, TierKind, TierSpec
from repro.models.registry import get_model
from repro.serve.kvpage import KVPager
from repro.serve.scheduler import ServeScheduler, StreamState


def mem_tier(capacity=10**9):
    return MemoryTier(TierSpec(TierKind.DRAM, capacity, 1e9, 1e9, 1e-6))


def two_level(cache_capacity=200, promotion=None, admission_fraction=None):
    cache, glob = mem_tier(cache_capacity), mem_tier()
    stack = TierStack([("cache", cache), ("global", glob)],
                      promotion=promotion, admission_fraction=admission_fraction)
    return stack, cache, glob


# ---------------------------------------------------------------------- #
# hit-rate-driven promotion (memory/stack.py)
# ---------------------------------------------------------------------- #


def test_promotes_only_after_k_hits():
    stack, cache, glob = two_level(promotion=HitRatePromotion(k=3, window=100))
    glob.put("k", b"cold-data")
    for expect_cached in (False, False, True):   # 3rd hit crosses k
        stack.get("k")
        assert cache.exists("k") == expect_cached
    assert stack.stats["promotions"] == 1


def test_hits_outside_window_do_not_promote():
    stack, cache, glob = two_level(promotion=HitRatePromotion(k=2, window=2))
    glob.put("k", b"v")
    glob.put("other", b"w")
    stack.get("k")
    stack.get("other")          # ages the window...
    stack.get("other")          # ...past k's first hit ('other' itself
    assert cache.exists("other")  # earns promotion with 2 in-window hits)
    stack.get("k")              # only 1 hit inside the window: stays cold
    assert not cache.exists("k")


def test_explicit_promote_bypasses_hit_gate():
    stack, cache, glob = two_level(promotion=HitRatePromotion(k=5, window=100))
    glob.put("k", b"v")
    stack.get("k", promote=True)
    assert cache.exists("k")


def test_observer_read_does_not_log_hits():
    stack, cache, glob = two_level(promotion=HitRatePromotion(k=2, window=100))
    glob.put("k", b"v")
    stack.get("k", promote=False)    # checkpoint-path observer read
    stack.get("k")                   # first *logged* hit
    assert not cache.exists("k")
    stack.get("k")                   # second logged hit: promote
    assert cache.exists("k")


def test_cold_blocks_demote_before_warm_ones():
    """A warm block (recent window hits) survives pressure even when LRU
    recency says otherwise: the cold block is demoted first."""
    stack, cache, glob = two_level(cache_capacity=100)
    stack.put("hot", b"h" * 40)
    stack.put("cold", b"c" * 40)
    stack.get("hot")
    stack.get("hot")
    stack.get("cold")       # cold is the most RECENT access (LRU-warmest)...
    stack.put("new", b"n" * 40)   # ...but has fewer window hits: demoted
    assert cache.exists("hot")
    assert not cache.exists("cold")
    assert glob.get("cold") == b"c" * 40


def test_stats_is_mapping_and_callable_with_miss_counters():
    stack, cache, glob = two_level()
    glob.put("k", b"v")
    stack.get("k")
    snap = stack.stats()
    assert isinstance(snap, dict)
    assert snap["misses_cache"] == 1 and snap["hits_global"] == 1
    assert stack.stats["misses_cache"] == 1   # mapping access still works
    stack.get("k")
    assert stack.stats()["hits_cache"] == 1


def test_cachefs_fill_respects_admission_control():
    """Regression: read-promotion through a cache-domain level must obey
    admission_fraction — an oversized value read through the CacheFS used
    to land in the cache unconditionally via get()'s implicit fill."""
    local, glob = mem_tier(100), mem_tier()
    fs = CacheFS(local, glob, mode="local-only")
    stack = TierStack([("beeond", fs), ("global", glob)],
                      admission_fraction=0.5)
    glob.put("big", b"B" * 60)       # fits the 100-byte cache raw...
    assert stack.get("big") == b"B" * 60
    assert not fs.cached("big"), "fill bypassed admission control"
    assert stack.stats["promotions"] == 0
    glob.put("small", b"s" * 20)     # within the admission fraction
    assert stack.get("small") == b"s" * 20
    assert fs.cached("small")
    assert stack.stats["promotions"] == 1


# ---------------------------------------------------------------------- #
# KVPager (serve/kvpage.py)
# ---------------------------------------------------------------------- #


def lane_like():
    return {
        "k": np.arange(24, dtype=np.float32).reshape(2, 3, 4),
        "v": jnp.ones((2, 5), jnp.bfloat16) * 1.5,
        "pos": np.int32(7),
    }


def test_pager_park_fetch_roundtrip_bytes():
    pager = KVPager.for_capacity(fast_bytes=1 << 20, page_bytes=64)
    lane = lane_like()
    nbytes = pager.park(3, lane)
    assert nbytes == serialize_state(lane).nbytes
    assert pager.is_parked(3) and pager.parked_sids() == [3]
    got = pager.fetch(3, lane_like())
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(lane)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert not pager.is_parked(3)    # fetch releases by default
    pager.close()


def test_pager_oversized_lane_routed_past_fast_tier():
    lane = lane_like()
    nbytes = serialize_state(lane).nbytes
    pager = KVPager.for_capacity(fast_bytes=2 * nbytes, page_bytes=4 * nbytes,
                                 admission_fraction=0.25)
    pager.park(0, lane)              # single page > 25% of fast: routed down
    assert pager.stack.stats["admission_routed"] >= 1
    assert pager.level_used()["hbm"] == 0
    pager.close()


def test_pager_unpaged_park_is_all_or_nothing():
    lane = lane_like()
    nbytes = serialize_state(lane).nbytes
    pager = KVPager.for_capacity(fast_bytes=int(1.5 * nbytes), paged=False,
                                 page_bytes=max(1, nbytes // 4))
    pager.park(0, lane)
    other = lane_like()
    other["k"] = other["k"] + 1.0    # distinct content: no page dedups
    before = pager.pooled_pages()
    with pytest.raises(CapacityError):
        pager.park(1, other)         # no lower tier to spill to
    # the failed park left no partial pages (or references) behind
    assert pager.pooled_pages() == before
    assert pager.parked_sids() == [0]
    pager.close()


def test_pager_identical_content_parks_share_pages():
    """Content-addressed pool: two streams with byte-identical lanes hold
    references to ONE set of pooled pages — a second park moves no bytes
    (and fits where a second copy would not)."""
    lane = lane_like()
    nbytes = serialize_state(lane).nbytes
    pager = KVPager.for_capacity(fast_bytes=int(1.5 * nbytes), paged=False,
                                 page_bytes=max(1, nbytes // 4))
    pager.park(0, lane)
    put_before = pager.stats()["kv_pages_put"]
    pager.park(1, lane_like())       # same bytes: pure reference bump
    assert pager.stats()["kv_pages_put"] == put_before
    assert pager.stats()["kv_page_dedup_hits"] > 0
    assert pager.pooled_bytes() < pager.parked_bytes()
    # releasing one stream keeps the shared pages for the other
    pager.release(0)
    got = pager.fetch(1, lane_like())
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(lane)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert pager.pooled_pages() == 0  # last reference dropped on fetch
    pager.close()


def test_resume_retains_baseline_so_repark_skips_clean_pages():
    """The round-robin cycle: park -> resume (release=False) -> park.
    The resume retains the table as a dirty-tracking baseline, so the
    second park re-puts nothing for unchanged bytes."""
    pager = KVPager.for_capacity(fast_bytes=1 << 20, page_bytes=64)
    lane = lane_like()
    pager.park(5, lane)
    got = pager.fetch(5, lane_like(), release=False)   # resume into a slot
    assert not pager.is_parked(5)          # not parked: it is decoding
    assert pager.parked_sids() == []
    assert pager.table_sids() == [5]       # ...but the baseline is live
    put_before = pager.stats()["kv_pages_put"]
    pager.park(5, got)                     # quantum expired, nothing decoded
    st = pager.stats()
    assert st["kv_pages_put"] == put_before
    assert st["kv_clean_page_skips"] > 0
    assert pager.is_parked(5)
    pager.release(5)
    assert pager.pooled_pages() == 0
    pager.close()


def test_pager_repark_skips_clean_pages():
    """Per-page dirty tracking: re-parking a stream whose bytes did not
    change re-puts nothing (content hash compare), counted in stats()."""
    pager = KVPager.for_capacity(fast_bytes=1 << 20, page_bytes=64)
    lane = lane_like()
    pager.park(5, lane)
    put_before = pager.stats()["kv_pages_put"]
    pager.park(5, lane_like())       # byte-identical re-park
    st = pager.stats()
    assert st["kv_pages_put"] == put_before
    assert st["kv_clean_page_skips"] > 0
    # a genuinely dirty page is re-put; clean neighbours still skip
    # (only `pos` changes — it lives in the last page, `k`'s page is clean)
    dirty = lane_like()
    dirty["pos"] = np.int32(9)
    pager.park(5, dirty)
    st2 = pager.stats()
    assert st2["kv_pages_put"] > put_before
    assert st2["kv_clean_page_skips"] > st["kv_clean_page_skips"]
    got = pager.fetch(5, lane_like())
    assert int(got["pos"]) == 9
    pager.close()


# ---------------------------------------------------------------------- #
# ServeScheduler (serve/scheduler.py)
# ---------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def served_model():
    cfg = get_config("phi3-mini-3.8b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def reference_decode(cfg, model, params, prompt, max_new, max_len):
    """Independent greedy batch-1 decode loop (no scheduler machinery)."""
    cache = model.init_cache(cfg, 1, max_len)
    toks = list(prompt)
    pos = 0
    out = []
    while len(out) < max_new and pos < max_len:
        tok = toks[pos]
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([tok], jnp.int32), jnp.int32(pos), cfg)
        pos += 1
        if pos >= len(prompt):
            nxt = int(np.asarray(logits.argmax(axis=-1))[0])
            toks.append(nxt)
            out.append(nxt)
    return out


def make_paged_scheduler(cfg, model, params, slots, max_len, session=None,
                         quantum=3, fast_lanes=3):
    lane_bytes = serialize_state(
        jax.device_get(model.init_cache(cfg, 1, max_len))).nbytes
    pager = KVPager.for_capacity(fast_bytes=fast_lanes * lane_bytes,
                                 page_bytes=max(1024, lane_bytes // 4))
    return ServeScheduler(cfg, model, params, slots=slots, max_len=max_len,
                          pager=pager, session=session, quantum=quantum)


def test_oversubscribed_paged_decode_matches_reference(served_model):
    """8 streams over 2 slots with parking/resume through the tier stack:
    every stream's output must equal an independent batch-1 decode."""
    cfg, model, params = served_model
    max_len, max_new = 24, 5
    sched = make_paged_scheduler(cfg, model, params, slots=2, max_len=max_len)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(rng.integers(2, 7)))
               for _ in range(8)]
    sids = [sched.submit(p, max_new=max_new) for p in prompts]
    sched.run()
    assert sched.stats["parked"] > 0, "oversubscription must exercise paging"
    assert sched.stats["max_resident"] == 8
    for sid, prompt in zip(sids, prompts):
        want = reference_decode(cfg, model, params, list(prompt), max_new,
                                max_len)
        assert sched.output(sid) == want, f"stream {sid} diverged"
    sched.close()


def test_unpaged_fast_tier_limits_residency(served_model):
    cfg, model, params = served_model
    max_len = 24
    lane_bytes = serialize_state(
        jax.device_get(model.init_cache(cfg, 1, max_len))).nbytes
    kw = dict(slots=2, max_len=max_len, quantum=2)

    def run_one(paged):
        pager = KVPager.for_capacity(fast_bytes=3 * lane_bytes, paged=paged,
                                     page_bytes=max(1024, lane_bytes // 4))
        sched = ServeScheduler(cfg, model, params, pager=pager, **kw)
        rng = np.random.default_rng(5)
        for _ in range(7):
            sched.submit(rng.integers(0, cfg.vocab_size, size=4), max_new=4)
        sched.run()
        stats = dict(sched.stats)
        outs = {sid: sched.output(sid) for sid in sched.streams}
        sched.close()
        return stats, outs

    flat_stats, flat_outs = run_one(paged=False)
    paged_stats, paged_outs = run_one(paged=True)
    assert flat_stats["park_failures"] > 0
    assert paged_stats["park_failures"] == 0
    assert paged_stats["max_resident"] == 7
    assert paged_stats["max_resident"] > flat_stats["max_resident"]
    assert flat_outs == paged_outs   # placement never changes the tokens


def test_multi_stream_kill_restore_byte_identity(served_model, tmp_path):
    """Mid-decode kill with streams active, parked, waiting and done; a
    FRESH scheduler restores the stream set from the checkpoint alone and
    finishes every stream byte-identically."""
    cfg, model, params = served_model
    max_len, max_new, slots = 24, 5, 2
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(rng.integers(2, 7)))
               for _ in range(8)]

    ref = make_paged_scheduler(cfg, model, params, slots, max_len)
    for p in prompts:
        ref.submit(p, max_new=max_new)
    ref.run()
    want = {sid: ref.output(sid) for sid in ref.streams}
    ref.close()

    cluster = VirtualCluster(4, 0, root=tmp_path)
    with ResilienceSession.for_cluster(cluster, strategy=Strategy.XOR,
                                       procs_per_node=2) as session:
        s1 = make_paged_scheduler(cfg, model, params, slots, max_len,
                                  session=session)
        for p in prompts:
            s1.submit(p, max_new=max_new)
        s1.run(max_steps=9)
        states = {s.state for s in s1.streams.values()}
        assert StreamState.PARKED in states, "kill point must have parked streams"
        s1.save()
        saved_step = s1.step_count
        s1.close()

        s2 = make_paged_scheduler(cfg, model, params, slots, max_len,
                                  session=session)
        got_step = s2.restore()
        assert got_step == saved_step
        s2.run()
        assert {sid: s2.output(sid) for sid in s2.streams} == want
        s2.close()


def test_engine_decode_tolerates_extra_scheduler_streams(served_model):
    """Regression: a caller may run extra streams through `.scheduler`;
    the engine's lockstep decode must only read its own rows and stop
    cleanly when they finish (it used to KeyError on the foreign sid)."""
    from repro.serve.engine import ServeEngine

    cfg, model, params = served_model
    eng = ServeEngine(cfg, model, params, batch=2, max_len=16)
    eng.prefill(jnp.zeros((2, 3), jnp.int32))
    eng.scheduler.submit([1, 2], max_new=2)    # foreign short stream
    out = eng.decode(50)
    assert len(out) == 16 - 3                  # engine rows ran to max_len
    assert all(o.shape == (2,) for o in out)
    eng.close()


def test_restore_rejects_mismatched_geometry(served_model, tmp_path):
    cfg, model, params = served_model
    cluster = VirtualCluster(4, 0, root=tmp_path)
    with ResilienceSession.for_cluster(cluster, strategy=Strategy.XOR,
                                       procs_per_node=2) as session:
        s1 = make_paged_scheduler(cfg, model, params, slots=2, max_len=24,
                                  session=session)
        s1.submit([1, 2, 3], max_new=2)
        s1.run(max_steps=2)
        s1.save()
        s1.close()
        s2 = make_paged_scheduler(cfg, model, params, slots=4, max_len=24,
                                  session=session)
        with pytest.raises(ValueError, match="slots=2"):
            s2.restore()
        s2.close()


# ---------------------------------------------------------------------- #
# FailureHistoryPolicy (api/policy.py)
# ---------------------------------------------------------------------- #


def test_failure_history_ema_tracks_gaps():
    p = FailureHistoryPolicy(mtbf_s=1000.0, ema=0.5)
    p.observe_failure(0.0)
    assert p.mtbf_estimate_s == 1000.0   # first failure: no gap yet
    p.observe_failure(100.0)             # gap 100 -> 0.5*1000 + 0.5*100
    assert p.mtbf_estimate_s == pytest.approx(550.0)
    p.observe_failure(150.0)             # gap 50
    assert p.mtbf_estimate_s == pytest.approx(300.0)
    assert p.failures_observed == 3


def test_failure_history_dedupes_same_incident_reports():
    """The trainer invalidates a node at the failure AND after recovery;
    the second report lands within min_gap_s and must not fold a
    near-zero gap into the MTBF estimate."""
    p = FailureHistoryPolicy(mtbf_s=1000.0, ema=0.5, min_gap_s=1.0)
    p.observe_failure(0.0)
    p.observe_failure(0.010)             # recovery-side duplicate: ignored
    assert p.failures_observed == 1
    assert p.mtbf_estimate_s == 1000.0
    p.observe_failure(200.0)             # a genuinely separate incident
    assert p.failures_observed == 2
    assert p.mtbf_estimate_s == pytest.approx(600.0)


def test_failure_history_tightens_and_loosens_engine_knobs():
    p = FailureHistoryPolicy(mtbf_s=3600.0, ema=1.0, min_keep=2, max_keep=8,
                             max_flush_every=4, tight_mtbf_s=60.0,
                             loose_mtbf_s=86400.0)
    # frequent failures: full paranoia
    p.observe_failure(0.0)
    p.observe_failure(10.0)
    assert p.engine_hints() == {"keep": 8, "flush_every": 1}
    # failures a day apart: fully relaxed
    p2 = FailureHistoryPolicy(mtbf_s=3600.0, ema=1.0, min_keep=2, max_keep=8,
                              max_flush_every=4, tight_mtbf_s=60.0,
                              loose_mtbf_s=86400.0)
    p2.observe_failure(0.0)
    p2.observe_failure(90000.0)
    assert p2.engine_hints() == {"keep": 2, "flush_every": 4}
    # cadence comes from Daly at the live MTBF estimate
    assert p.should_checkpoint(PolicyContext(step=1, now_s=0.0))  # bootstrap


def test_session_applies_failure_history_hints(tmp_path):
    cluster = VirtualCluster(4, 0, root=tmp_path)
    # seeded below tight_mtbf_s: the policy starts paranoid, and the
    # session must push those knobs into the engine at the first
    # failure-observation point
    policy = FailureHistoryPolicy(mtbf_s=30.0, tight_mtbf_s=60.0,
                                  loose_mtbf_s=86400.0, max_keep=8)
    with ResilienceSession.for_cluster(cluster, policy=policy,
                                       procs_per_node=2) as session:
        baseline = session.scr.keep
        session.invalidate_node(1)
        assert policy.failures_observed == 1
        assert session.scr.keep == 8
        assert session.scr.flush_every == 1
        assert session.scr.keep >= baseline
        # the recovery-side re-invalidation of the same incident is
        # deduplicated, not folded into the MTBF estimate
        session.invalidate_node(1)
        assert policy.failures_observed == 1
