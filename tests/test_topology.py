"""VirtualCluster topology: buddy pairing, XOR groups, failure machinery."""

import time

import pytest

from repro.cluster.topology import Module, NodeFailure, NodeState, VirtualCluster


def test_modules_and_ranks(tmp_cluster):
    assert tmp_cluster.size == 8
    assert tmp_cluster.ranks(Module.CLUSTER) == [0, 1, 2, 3]
    assert tmp_cluster.ranks(Module.BOOSTER) == [4, 5, 6, 7]


def test_buddy_pairing_within_module(tmp_cluster):
    for rank in range(8):
        buddy = tmp_cluster.buddy_of(rank)
        assert buddy != rank
        assert tmp_cluster.node(buddy).module == tmp_cluster.node(rank).module


def test_buddy_is_cyclic_not_self(tmp_path):
    cl = VirtualCluster(3, 0, root=tmp_path)  # odd module size
    seen = {cl.buddy_of(r) for r in range(3)}
    assert len(seen) == 3  # a 3-cycle covers everyone


def test_xor_groups_partition_modules(tmp_cluster):
    all_ranks = sorted(r for g in tmp_cluster.xor_groups for r in g)
    assert all_ranks == list(range(8))
    for g in tmp_cluster.xor_groups:
        modules = {tmp_cluster.node(r).module for r in g}
        assert len(modules) == 1  # topology-aware: groups stay in-module


def test_xor_group_tail_folding(tmp_path):
    cl = VirtualCluster(5, 0, root=tmp_path, xor_group_size=4)
    assert cl.xor_groups == [[0, 1, 2, 3, 4]]  # singleton folded in


def test_node_failure_wipes_nvm(tmp_cluster):
    p = tmp_cluster.nvm_path(2)
    (p / "data.bin").write_bytes(b"x")
    tmp_cluster.fail(2, NodeState.FAILED_NODE)
    with pytest.raises(NodeFailure):
        tmp_cluster.nvm_path(2)
    tmp_cluster.recover(2)
    assert not (tmp_cluster.nvm_path(2) / "data.bin").exists()


def test_transient_failure_keeps_nvm(tmp_cluster):
    p = tmp_cluster.nvm_path(2)
    (p / "data.bin").write_bytes(b"x")
    tmp_cluster.fail(2, NodeState.FAILED_TRANSIENT)
    tmp_cluster.recover(2)
    assert (tmp_cluster.nvm_path(2) / "data.bin").read_bytes() == b"x"


def test_armed_failure_fires_once(tmp_cluster):
    tmp_cluster.arm_failure(1, NodeState.FAILED_TRANSIENT)
    with pytest.raises(NodeFailure):
        tmp_cluster.maybe_fail(1)
    tmp_cluster.recover(1)
    tmp_cluster.maybe_fail(1)  # disarmed now


def test_failure_detector(tmp_cluster):
    for r in range(8):
        tmp_cluster.heartbeat(r)
    tmp_cluster.node(3).last_heartbeat -= 100.0
    assert tmp_cluster.detect_failures(timeout_s=30.0) == [3]


def test_straggler_detector(tmp_cluster):
    now = time.monotonic()
    for r in range(8):
        tmp_cluster.node(r).last_heartbeat = now - 1.0
    tmp_cluster.node(5).last_heartbeat = now - 60.0
    assert tmp_cluster.detect_stragglers(factor=3.0) == [5]


def test_elastic_resize_preserves_root(tmp_cluster):
    bigger = tmp_cluster.resize(8, 8)
    assert bigger.size == 16
    assert bigger.root == tmp_cluster.root
