"""Property harness for the device page pool allocator.

The in-jit paged decode path stands on one host-side invariant: the
allocator never leaks a page and never double-frees one, across any
interleaving of admit / park / spill / resume / finish / kill.  These
tests drive randomized operation sequences against a shadow model and
check, after every operation:

* conservation — ``free + used == n_pages``, the trash page is never
  allocated, no physical page is both free and referenced;
* exact refcounts — every page's refcount equals the number of live
  stream tables referencing it plus its digest binding (a refcount is
  zero iff no live stream and no resident prefix digest references it);
* pager dedup never inflates — ``pooled_bytes <= parked_bytes``;
* kill (snapshot/load) round-trips the allocator bit-exactly.

With `hypothesis` installed (CI fast lane) the sequences are minimized
counter-examples; without it the fixed-seed random fallback runs the
same core.
"""

import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.memory.tiers import CapacityError
from repro.serve.kvpage import KVPager
from repro.serve.pagepool import TRASH_PAGE, DevicePagePool

N_PAGES = 10          # tiny on purpose: pressure paths fire constantly
PAGE_TOKENS = 2
MAX_LEN = 8           # -> 4 pages per lane
PAGES_PER_LANE = MAX_LEN // PAGE_TOKENS


def tiny_pool() -> DevicePagePool:
    template = {
        "k": np.zeros((2, 1, MAX_LEN, 2, 3), np.float32),
        "v": np.zeros((2, 1, MAX_LEN, 2, 3), np.float32),
    }
    axes = {
        "k": ("layers", "batch", "kv_seq", "heads", "head_dim"),
        "v": ("layers", "batch", "kv_seq", "heads", "head_dim"),
    }
    return DevicePagePool(template, axes, PAGE_TOKENS, N_PAGES)


class Harness:
    """Drives pool + pager and mirrors them in a pure-python shadow."""

    def __init__(self, pager=None):
        self.pool = tiny_pool()
        self.pager = pager if pager is not None else KVPager.for_capacity(
            fast_bytes=10**8, page_bytes=256)
        self.tables = {}           # sid -> [phys] (pool-resident streams)
        self.spilled = set()       # sids parked out through the pager
        self.bound = {}            # digest -> phys (shadow of residency)
        self.next_sid = 0

    # -- operations (each mirrors one scheduler-side transition) -------- #

    def admit(self, share_digest):
        """A fresh stream allocates its table; with ``share_digest`` its
        first page references the digest-bound page instead."""
        sid, self.next_sid = self.next_sid, self.next_sid + 1
        table = []
        try:
            if share_digest is not None and share_digest in self.bound:
                phys = self.bound[share_digest]
                self.pool.ref(phys)
                table.append(phys)
            table.extend(self.pool.alloc(PAGES_PER_LANE - len(table)))
        except CapacityError:
            for phys in table:
                self.pool.deref(phys)
            return
        self.tables[sid] = table

    def bind(self, digest):
        """Pin a fresh page as a prefix digest's pool-resident copy."""
        if digest in self.bound:
            return
        try:
            phys = self.pool.alloc(1)[0]
        except CapacityError:
            return
        self.pool.bind_digest(digest, phys)
        self.pool.deref(phys)          # keep only the binding's reference
        self.bound[digest] = phys

    def drop(self, digest):
        if digest in self.bound:
            self.pool.drop_digest(digest)
            del self.bound[digest]

    def spill(self, pick):
        """Park one resident stream's pages out through the pager."""
        if not self.tables:
            return
        sid = sorted(self.tables)[pick % len(self.tables)]
        table = self.tables.pop(sid)
        self.pager.park_pages(sid, [self.pool.page_blob(p) for p in table])
        for phys in table:
            self.pool.deref(phys)
        self.spilled.add(sid)

    def resume(self, pick):
        """Refill one spilled stream into freshly allocated pages."""
        if not self.spilled:
            return
        sid = sorted(self.spilled)[pick % len(self.spilled)]
        try:
            phys = self.pool.alloc(PAGES_PER_LANE)
        except CapacityError:
            return
        blobs = self.pager.fetch_pages(sid, release=True)
        assert len(blobs) == PAGES_PER_LANE
        for p, b in zip(phys, blobs):
            self.pool.write_blob(p, b)
        self.spilled.remove(sid)
        self.tables[sid] = phys

    def finish(self, pick):
        if not self.tables:
            return
        sid = sorted(self.tables)[pick % len(self.tables)]
        for phys in self.tables.pop(sid):
            self.pool.deref(phys)

    def kill(self):
        """Process death: snapshot -> fresh pool -> load must round-trip
        the allocator (refcounts, free list, digest map) bit-exactly."""
        arrays = self.pool.snapshot()
        refs = self.pool.refcounts()
        digests = self.pool.resident_digests()
        fresh = tiny_pool()
        fresh.load(arrays, refs, digests)
        assert fresh.refcounts() == refs
        assert fresh.resident_digests() == digests
        assert fresh.free_pages() == self.pool.free_pages()
        self.pool = fresh

    # -- invariants -------------------------------------------------------- #

    def check(self):
        pool = self.pool
        assert pool.free_pages() + pool.used_pages() == N_PAGES
        assert pool.refcount(TRASH_PAGE) == 0
        # exact refcounts: table references + digest bindings, nothing else
        want = {}
        for table in self.tables.values():
            for phys in table:
                want[phys] = want.get(phys, 0) + 1
        for phys in self.bound.values():
            want[phys] = want.get(phys, 0) + 1
        assert pool.refcounts() == want, (
            f"leak or double-free: pool says {pool.refcounts()}, "
            f"live references say {want}")
        # dedup never inflates: the pager stores at most the logical bytes
        assert self.pager.pooled_bytes() <= self.pager.parked_bytes()

    def drain(self):
        """Tear everything down; the pool must come back empty."""
        for pick in range(len(self.tables)):
            self.finish(0)
        for digest in list(self.bound):
            self.drop(digest)
        for sid in list(self.spilled):
            self.pager.release(sid)
            self.spilled.remove(sid)
        assert self.pool.used_pages() == 0, self.pool.refcounts()
        assert self.pool.free_pages() == N_PAGES
        assert self.pager.pooled_bytes() == 0


DIGESTS = ["dA", "dB", "dC"]


def run_sequence(ops):
    """ops: list of (code, arg) pairs; the deterministic property core."""
    h = Harness()
    for code, arg in ops:
        if code == 0:
            h.admit(share_digest=DIGESTS[arg % len(DIGESTS)]
                    if arg % 2 else None)
        elif code == 1:
            h.bind(DIGESTS[arg % len(DIGESTS)])
        elif code == 2:
            h.drop(DIGESTS[arg % len(DIGESTS)])
        elif code == 3:
            h.spill(arg)
        elif code == 4:
            h.resume(arg)
        elif code == 5:
            h.finish(arg)
        elif code == 6:
            h.kill()
        h.check()
    h.drain()


def test_fixed_seed_random_sequences():
    """Fallback property run: 40 random op sequences, fixed seed."""
    rng = np.random.default_rng(1234)
    for _ in range(40):
        n = int(rng.integers(5, 30))
        ops = [(int(rng.integers(0, 7)), int(rng.integers(0, 8)))
               for _ in range(n)]
        run_sequence(ops)


def test_directed_share_then_kill_then_drain():
    """Worst case by construction: share one digest page across several
    streams, kill mid-flight, spill under pressure, then drain."""
    ops = ([(1, 0)] + [(0, 1)] * 4      # bind dA, 4 streams sharing it
           + [(6, 0)]                   # kill/restore
           + [(3, 0), (3, 1)]           # spill two streams
           + [(0, 3)] * 3               # admit more (pool now tight)
           + [(4, 0), (6, 0), (2, 0)])  # resume, kill again, drop dA
    run_sequence(ops)


@given(st.lists(st.tuples(st.integers(min_value=0, max_value=6),
                          st.integers(min_value=0, max_value=7)),
                min_size=1, max_size=40))
@settings(max_examples=60, deadline=None)
def test_pool_never_leaks_or_double_frees(ops):
    """Hypothesis property: ANY admit/park/resume/finish/kill sequence
    keeps refcounts exactly equal to live references and drains to an
    empty pool."""
    run_sequence(ops)


class FleetHarness:
    """Two Harnesses (fleet workers A and B) whose pager stacks share
    one SharedTier domain.  On top of the per-pool invariants, the fleet
    ops model cross-process prefix-page sharing: ``publish`` copies a
    pool-resident digest page into the shared level, ``adopt`` lets the
    *other* pool bind it from the shared bytes.  The shadow ``published``
    map pins the round-trip: adopted page bytes must equal the bytes the
    publisher shipped — across any interleaving with the single-pool ops
    (including kills of either pool, and whole-worker replacement via
    ``kill_worker``)."""

    def __init__(self, root):
        self.root = root
        self.members = [self._fresh_member() for _ in range(2)]
        self.published = {}        # digest -> bytes as last published

    def _fresh_member(self):
        from repro.memory.shared import SharedTier

        return Harness(KVPager.for_fleet(SharedTier(self.root),
                                         fast_bytes=10**8, page_bytes=256))

    def publish(self, who, pick):
        h = self.members[who]
        if not h.bound:
            return
        digest = sorted(h.bound)[pick % len(h.bound)]
        blob = bytes(h.pool.page_blob(h.bound[digest]))
        try:
            h.pager.stack.put_at("shared", f"kv/prefix/{digest}.bin", blob)
        except CapacityError:
            return
        self.published[digest] = blob

    def adopt(self, who, pick):
        h = self.members[who]
        if not self.published:
            return
        digest = sorted(self.published)[pick % len(self.published)]
        if digest in h.bound:
            return
        try:
            data = h.pager.stack.get(f"kv/prefix/{digest}.bin")
        except KeyError:
            return
        try:
            phys = h.pool.alloc(1)[0]
        except CapacityError:
            return
        h.pool.write_blob(phys, data)
        h.pool.bind_digest(digest, phys)
        h.pool.deref(phys)
        h.bound[digest] = phys
        # the round-trip claim: shared-tier transport is byte-exact
        assert bytes(h.pool.page_blob(phys)) == self.published[digest]

    def kill_worker(self, who):
        """Unplanned worker death (the fig13 scenario at allocator
        scale): the member's pool, pager and local tiers vanish with the
        process; a replacement joins over the same shared domain.  The
        survivor's refcounts/bindings must be untouched, and everything
        in ``published`` must stay byte-exact adoptable by the
        replacement — the shared level owns the bytes, not the worker."""
        self.members[who] = self._fresh_member()

    def check(self):
        for h in self.members:
            h.check()

    def drain(self):
        for h in self.members:
            h.drain()


def run_fleet_sequence(ops, root):
    """ops: (code, arg) with code 0-6 the single-pool ops (arg's low bit
    picks the pool), 7 publish, 8 adopt, 9 kill-and-replace a worker."""
    f = FleetHarness(root)
    for code, arg in ops:
        who = arg & 1
        if code == 7:
            f.publish(who, arg >> 1)
        elif code == 8:
            f.adopt(who, arg >> 1)
        elif code == 9:
            f.kill_worker(who)
        else:
            h = f.members[who]
            pick = arg >> 1
            if code == 0:
                h.admit(share_digest=DIGESTS[pick % len(DIGESTS)]
                        if pick % 2 else None)
            elif code == 1:
                h.bind(DIGESTS[pick % len(DIGESTS)])
            elif code == 2:
                h.drop(DIGESTS[pick % len(DIGESTS)])
            elif code == 3:
                h.spill(pick)
            elif code == 4:
                h.resume(pick)
            elif code == 5:
                h.finish(pick)
            elif code == 6:
                h.kill()
        f.check()
    f.drain()


def test_fleet_fixed_seed_random_sequences(tmp_path):
    rng = np.random.default_rng(4321)
    for i in range(25):
        n = int(rng.integers(5, 30))
        ops = [(int(rng.integers(0, 10)), int(rng.integers(0, 16)))
               for _ in range(n)]
        run_fleet_sequence(ops, tmp_path / f"dom{i}")


def test_directed_publish_adopt_across_pools(tmp_path):
    """By construction: A binds + publishes, B adopts + shares it into
    streams, A drops and recycles the page, B's adopted copy survives;
    then B kills and everything drains."""
    ops = [(1, 0),             # A binds dA
           (7, 0),             # A publishes dA
           (8, 1),             # B adopts dA
           (0, 3),             # B admits a stream sharing dA
           (2, 0),             # A drops dA (B's copy must be unaffected)
           (0, 2),             # A admits a plain stream over the page
           (6, 1),             # B kill/restore round-trip
           (5, 1), (2, 1)]     # B finishes the stream, drops dA
    run_fleet_sequence(ops, tmp_path / "dom")


def test_directed_worker_death_and_adoption(tmp_path):
    """By construction: A binds + publishes dA and runs streams on both
    members; A dies unplanned (code 9) mid-traffic; the replacement A
    and the survivor B both adopt dA byte-exact from the shared domain;
    B then kill/restores and drops dA cleanly."""
    ops = [(1, 0),             # A binds dA
           (7, 0),             # A publishes dA
           (0, 2),             # a plain stream on A
           (0, 3),             # a stream on B
           (9, 0),             # A dies; fresh member joins the domain
           (8, 0),             # replacement A adopts dA (byte-exact)
           (8, 1),             # B adopts dA too
           (6, 1),             # B kill/restore round-trip
           (2, 1)]             # B drops dA
    run_fleet_sequence(ops, tmp_path / "dom")


@given(st.lists(st.tuples(st.integers(min_value=0, max_value=9),
                          st.integers(min_value=0, max_value=15)),
                min_size=1, max_size=30))
@settings(max_examples=40, deadline=None)
def test_fleet_pools_never_leak_or_corrupt(tmp_path_factory, ops):
    """Hypothesis property: ANY interleaving of the two pools' ops plus
    publish/adopt keeps both allocators exact and the shared-tier
    round-trip byte-exact."""
    run_fleet_sequence(ops, tmp_path_factory.mktemp("fleetdom"))


def test_trash_page_is_never_allocatable():
    pool = tiny_pool()
    seen = set()
    while pool.free_pages():
        seen.update(pool.alloc(1))
    assert TRASH_PAGE not in seen
    assert len(seen) == N_PAGES


def test_alloc_is_all_or_nothing():
    pool = tiny_pool()
    pool.alloc(N_PAGES - 1)
    free_before = pool.free_pages()
    with pytest.raises(CapacityError):
        pool.alloc(2)
    assert pool.free_pages() == free_before
