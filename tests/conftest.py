import numpy as np
import pytest


@pytest.fixture
def tmp_cluster(tmp_path):
    from repro.cluster.topology import VirtualCluster

    cl = VirtualCluster(n_cluster=4, n_booster=4, root=tmp_path / "run",
                        xor_group_size=4)
    yield cl
    cl.teardown()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
