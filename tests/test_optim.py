"""AdamW + error-feedback gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compress_grads, decompress_grads, init_residual


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    params = {"x": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    target = jnp.array([1.0, 2.0])
    for step in range(200):
        grads = {"x": 2 * (params["x"] - target)}
        params, opt = adamw_update(cfg, params, grads, opt, jnp.int32(step))
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_clips_global_norm():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros((3,))}
    opt = adamw_init(params)
    huge = {"x": jnp.full((3,), 1e9)}
    new_params, _ = adamw_update(cfg, params, huge, opt, jnp.int32(0))
    assert np.all(np.isfinite(np.asarray(new_params["x"])))
    assert np.abs(np.asarray(new_params["x"])).max() < 1.0


def test_adamw_moments_fp32():
    params = {"x": jnp.zeros((3,), jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt["m"]["x"].dtype == jnp.float32


def test_compression_roundtrip_small_error():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(256,)), jnp.float32)}
    r = init_residual(g)
    q, s, r2 = compress_grads(g, r)
    assert q["w"].dtype == jnp.int8
    back = decompress_grads(q, s)
    err = np.abs(np.asarray(back["w"]) - np.asarray(g["w"])).max()
    assert err <= float(s["w"]) / 2 + 1e-7


def test_error_feedback_is_unbiased_over_steps():
    """Accumulated decompressed grads converge to accumulated true grads."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32) * 1e-3
    r = init_residual({"w": g_true})
    total = np.zeros(64)
    steps = 50
    for _ in range(steps):
        q, s, r = compress_grads({"w": g_true}, r)
        total += np.asarray(decompress_grads(q, s)["w"])
    # with error feedback, mean recovered grad ~= true grad
    np.testing.assert_allclose(total / steps, np.asarray(g_true), atol=1e-5)
