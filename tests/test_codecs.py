"""Tier codecs: round-trips, stack policy, and the int8 KV paths.

Three layers of guarantees, matching docs/architecture.md's codec table:

* **byte level** — ZlibCodec round-trips any blob exactly; Int8Codec
  round-trips within ``scale/2`` per element (scale = per-block
  ``max|x|/127``) and is a *fixed point*: re-encoding a decoded blob
  reproduces the same bytes, so content addressing stays stable across
  park/resume cycles under a lossy tier;
* **stack level** — a ``kv`` codec rule encodes exactly the writes that
  land past the fast tier (demotion/spill) and decodes every read;
  classes without a rule (checkpoint fragments) stay plaintext;
* **serving level** — park -> demote -> promote -> resume through an
  int8 stack keeps KV within quantization tolerance, the zlib path
  stays token-identical to the uncompressed baseline, and the quantized
  Pallas kernel matches the fp32 kernel within the allclose gate.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.memory.codecs import (CodecRule, Int8Codec, ZlibCodec, decode_blob,
                                 int8_quantize, is_encoded, make_codec)
from repro.memory.stack import HitRatePromotion, KeyClass, TierStack
from repro.memory.tiers import MemoryTier, TierKind, TierSpec


def _stack(fast_bytes, codecs=None):
    def tier(kind, cap):
        return MemoryTier(TierSpec(kind, cap, 1e9, 1e9, 1e-6))

    return TierStack(
        [("hbm", tier(TierKind.HBM, fast_bytes)),
         ("dram", tier(TierKind.DRAM, 1 << 26))],
        admission_fraction=0.5,
        promotion=HitRatePromotion(k=2, window=64),
        codecs=codecs,
    )


# ---------------------------------------------------------------------- #
# byte-level round-trips
# ---------------------------------------------------------------------- #


@given(st.binary(max_size=4096))
@settings(max_examples=60, deadline=None)
def test_zlib_roundtrip_exact(data):
    codec = ZlibCodec()
    blob = codec.encode(data)
    assert is_encoded(blob)
    assert not is_encoded(data) or data[:6] == blob[:6]
    assert decode_blob(blob) == data
    # encoding a framed blob is a no-op (demotion can't double-encode)
    assert codec.encode(blob) == blob


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                min_size=1, max_size=300),
       st.integers(1, 48))
@settings(max_examples=60, deadline=None)
def test_int8_roundtrip_tolerance_and_fixed_point(vals, block):
    codec = Int8Codec(dtype="float32", block=block)
    x = np.asarray(vals, np.float32)
    blob = codec.encode(x.tobytes())
    back = np.frombuffer(decode_blob(blob), np.float32)
    assert back.shape == x.shape
    # per-block error bound: |x - q*s| <= s/2, s = max|block|/127
    n = x.size
    nblocks = -(-n // block)
    pad = np.zeros(nblocks * block, np.float32)
    pad[:n] = x
    s = np.abs(pad.reshape(nblocks, block)).max(axis=1) / 127.0
    bound = np.repeat(np.maximum(s, 1e-12), block)[:n] * 0.5 + 1e-6
    assert np.all(np.abs(back - x) <= bound)
    # fixed point: re-encoding decoded values reproduces them (up to a
    # couple of float32 ulps when the recomputed scale rounds differently)
    back2 = np.frombuffer(decode_blob(codec.encode(back.tobytes())),
                          np.float32)
    np.testing.assert_allclose(back2, back, rtol=1e-6, atol=0)


def test_int8_ragged_tail_and_empty():
    codec = Int8Codec(dtype="float32", block=8)
    # 10 bytes = 2 float32 + 2 raw tail bytes
    data = np.asarray([1.5, -3.25], np.float32).tobytes() + b"\x07\x09"
    back = decode_blob(codec.encode(data))
    assert len(back) == len(data) and back[-2:] == b"\x07\x09"
    assert decode_blob(codec.encode(b"")) == b""
    assert decode_blob(ZlibCodec().encode(b"")) == b""


def test_make_codec_knob():
    assert make_codec(None) is None and make_codec("none") is None
    assert make_codec("zlib").lossless
    c = make_codec("int8", dtype="bfloat16", block=16)
    assert not c.lossless and c.block == 16
    with pytest.raises(ValueError):
        make_codec("lz4")


@pytest.mark.parametrize("name", ["starcoder2-7b", "minicpm3-4b"])
def test_int8_on_model_family_kv_leaves(name):
    """Each family's KV cache leaves (their real dtype/shape) round-trip
    within tolerance, with one scale per last-axis channel."""
    from repro.configs import get_config
    from repro.models.registry import get_model

    cfg = get_config(name).reduced()
    model = get_model(cfg)
    cache = jax.device_get(model.init_cache(cfg, 1, 16))
    rng = np.random.default_rng(3)
    for leaf_name, leaf in sorted(cache.items()):
        arr = np.asarray(leaf)
        vals = rng.normal(size=arr.shape).astype(np.float32)
        arr = jnp.asarray(vals).astype(arr.dtype)
        host = np.asarray(arr)
        ch = int(arr.shape[-1])
        codec = Int8Codec(dtype=cfg.compute_dtype, block=ch)
        blob = codec.encode(host.tobytes())
        assert is_encoded(blob)
        # scale-shape check: one f32 scale per channel, no ragged pad
        n = host.size
        assert n % ch == 0
        payload = blob[16 + 20:]    # frame header + int8 head
        assert len(payload) == n + (n // ch) * 4
        back = np.frombuffer(decode_blob(blob),
                             host.dtype).reshape(host.shape)
        xf = np.asarray(jnp.asarray(host).astype(jnp.float32))
        bf = np.asarray(jnp.asarray(back).astype(jnp.float32))
        s = np.abs(xf.reshape(-1, ch)).max(axis=1, keepdims=True) / 127.0
        bound = np.maximum(s, 1e-12) * 0.5 + 2.0 ** -7 * np.abs(
            xf.reshape(-1, ch)) + 1e-6
        assert np.all(np.abs(bf.reshape(-1, ch) - xf.reshape(-1, ch))
                      <= bound), leaf_name


# ---------------------------------------------------------------------- #
# stack policy
# ---------------------------------------------------------------------- #


def test_stack_encodes_only_past_the_fast_tier():
    """A kv value admitted to the fast tier stays plaintext; one routed
    (or demoted) past it is stored encoded and decodes on read; classes
    without a rule never encode."""
    stack = _stack(4096, codecs={KeyClass.KV: CodecRule(ZlibCodec())})
    small = bytes(range(256)) * 4                     # 1 KiB: admitted fast
    big = b"\x11" * 8192                              # routed past hbm
    stack.put("kv/page/aa.bin", small)
    stack.put("kv/page/bb.bin", big)
    stack.put("ckpt/frag/cc.bin", big)                # no rule: plaintext
    raw = dict(stack.levels)
    assert not is_encoded(raw["hbm"].get("kv/page/aa.bin"))
    assert is_encoded(raw["dram"].get("kv/page/bb.bin"))
    assert not is_encoded(raw["dram"].get("ckpt/frag/cc.bin"))
    assert stack.get("kv/page/aa.bin") == small
    assert stack.get("kv/page/bb.bin") == big
    st_ = stack.stats()
    assert st_["kv_bytes_encoded"] == len(big)
    assert st_["kv_bytes_decoded"] == len(big)
    assert 0 < st_["kv_codec_ratio"] < 1
    stack.close()


def test_stack_lossy_rule_decodes_within_tolerance():
    vals = np.linspace(-2, 2, 4096, dtype=np.float32)
    stack = _stack(1024, codecs={
        KeyClass.KV: CodecRule(Int8Codec(dtype="float32", block=64))})
    stack.put("kv/page/dd.bin", vals.tobytes())       # too big for hbm
    back = np.frombuffer(stack.get("kv/page/dd.bin"), np.float32)
    assert np.max(np.abs(back - vals)) <= (2.0 / 127.0) * 0.5 + 1e-6
    stack.close()


def test_set_codec_after_construction():
    stack = _stack(1024)
    stack.set_codec(KeyClass.KV, CodecRule(ZlibCodec()))
    stack.put("kv/page/ee.bin", b"\x00" * 4096)
    assert stack.get("kv/page/ee.bin") == b"\x00" * 4096
    assert stack.stats()["kv_bytes_encoded"] == 4096
    stack.close()


# ---------------------------------------------------------------------- #
# quantized paged-attention kernel gates
# ---------------------------------------------------------------------- #


def _quant_case(b=2, s=32, hq=4, hkv=2, d=16, page=8, seed=31):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, s, hkv, d))
    vc = jax.random.normal(ks[2], (b, s, hkv, d))
    rng = np.random.default_rng(seed)
    lengths = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    return q, kc, vc, lengths, page


def test_quant_kernel_matches_jnp_quant_oracle():
    from repro.kernels.paged_attention import (
        paged_attention_pallas_quant, paged_attention_quant, paginate_cache,
        quantize_pages)

    q, kc, vc, lengths, page = _quant_case()
    k_pages, v_pages, table = paginate_cache(kc, vc, page)
    kq, ks_ = quantize_pages(k_pages)
    vq, vs_ = quantize_pages(v_pages)
    want = paged_attention_quant(q, kq, ks_, vq, vs_, table, lengths)
    got = paged_attention_pallas_quant(q, kq, ks_, vq, vs_, table, lengths,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-6, rtol=1e-5)


def test_quant_kernel_allclose_gate_vs_fp32_kernel():
    """THE acceptance gate: in-kernel dequant attention within 0.05 of
    the fp32 paged kernel on unit-normal KV."""
    from repro.kernels.paged_attention import (
        paged_attention_pallas, paged_attention_pallas_quant, paginate_cache,
        quantize_pages)

    q, kc, vc, lengths, page = _quant_case(seed=37)
    k_pages, v_pages, table = paginate_cache(kc, vc, page)
    kq, ks_ = quantize_pages(k_pages)
    vq, vs_ = quantize_pages(v_pages)
    want = paged_attention_pallas(q, k_pages, v_pages, table, lengths,
                                  interpret=True)
    got = paged_attention_pallas_quant(q, kq, ks_, vq, vs_, table, lengths,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.05, rtol=0.05)


def test_quant_multitok_matches_per_row():
    from repro.kernels.paged_attention import (
        paged_attention_pallas_quant, paged_attention_pallas_quant_multitok,
        paginate_cache, quantize_pages)

    b, s, t, page = 2, 24, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(41), 3)
    q = jax.random.normal(ks[0], (b, t, 4, 8))
    kc = jax.random.normal(ks[1], (b, s, 2, 8))
    vc = jax.random.normal(ks[2], (b, s, 2, 8))
    k_pages, v_pages, table = paginate_cache(kc, vc, page)
    kq, ks_ = quantize_pages(k_pages)
    vq, vs_ = quantize_pages(v_pages)
    base = np.asarray([5, 12], np.int32)
    positions = jnp.asarray(base[:, None] + np.arange(t)[None], jnp.int32)
    got = paged_attention_pallas_quant_multitok(
        q, kq, ks_, vq, vs_, table, positions, interpret=True)
    for i in range(t):
        want = paged_attention_pallas_quant(
            q[:, i], kq, ks_, vq, vs_, table,
            jnp.asarray(base + i + 1, jnp.int32), interpret=True)
        np.testing.assert_allclose(np.asarray(got[:, i]), np.asarray(want),
                                   atol=3e-6, rtol=1e-5)


# ---------------------------------------------------------------------- #
# serving: park -> demote -> promote -> resume under a kv codec
# ---------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def arch():
    from repro.configs import get_config
    from repro.models.registry import get_model

    cfg = get_config("starcoder2-7b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


MAX_LEN, MAX_NEW, PT = 24, 6, 4


def _serve(arch, kv_codec, pager=None, pool_pages=None):
    from repro.serve.scheduler import PagedServeScheduler

    cfg, model, params = arch
    sched = PagedServeScheduler(
        cfg, model, params, slots=2, max_len=MAX_LEN, page_tokens=PT,
        pool_pages=pool_pages, pager=pager, kv_codec=kv_codec, quantum=3)
    rng = np.random.default_rng(11)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size,
                                          size=int(rng.integers(2, 10)))))
               for _ in range(5)]
    sids = [sched.submit(p, max_new=MAX_NEW) for p in prompts]
    sched.run()
    return sched, [sched.output(sid) for sid in sids]


def test_zlib_spill_path_is_token_identical(arch):
    """Lossless codec end-to-end: spill -> demote-encode -> promote ->
    refill emits the exact baseline tokens, and the codec counters prove
    pages really crossed the codec boundary."""
    from repro.serve.kvpage import KVPager

    _, base = _serve(arch, None)
    pager = KVPager.for_capacity(fast_bytes=2048, kv_codec="zlib")
    sched, got = _serve(arch, "zlib", pager=pager,
                        pool_pages=3 * (MAX_LEN // PT))
    assert got == base
    assert sched.stats["spilled"] > 0
    st_ = pager.stats()
    assert st_["kv_bytes_encoded"] > 0 and st_["kv_bytes_decoded"] > 0


def test_int8_spill_path_matches_greedy_within_tolerance(arch):
    """Lossy codec end-to-end (park -> demote -> promote -> resume
    through the int8 stack, int8 pool residency): the emitted tokens
    stay in high agreement with the fp32 baseline — quantization noise
    may flip near-tie argmaxes but must not derail decode."""
    from repro.memory.stack import KeyClass as KC
    from repro.serve.kvpage import KVPager

    _, base = _serve(arch, None)
    pager = KVPager.for_capacity(fast_bytes=2048)
    sched, got = _serve(arch, "int8", pager=pager,
                        pool_pages=3 * (MAX_LEN // PT))
    assert sched.stats["spilled"] > 0
    # the scheduler auto-installed a lossy kv rule on the pager's stack
    rule = pager.stack.codec_for(KC.KV)
    assert rule is not None and not rule.codec.lossless
    assert pager.kv_lossy()
    assert pager.stats()["kv_bytes_encoded"] > 0
    agree = np.mean([a == b for x, y in zip(base, got)
                     for a, b in zip(x, y)])
    assert agree >= 0.8, f"token agreement {agree:.2f} under int8"


def test_int8_pager_lane_roundtrip_within_tolerance(arch):
    """Lane-level: park a real KV lane through an int8 stack small
    enough to demote every page, fetch it back, and check per-channel
    quantization tolerance on every leaf."""
    from repro.serve.kvpage import KVPager

    cfg, model, params = arch
    cache = model.init_cache(cfg, 1, MAX_LEN)
    pos = 0
    for tok in [3, 1, 4, 1, 5, 9, 2, 6]:
        _, cache = model.decode_step(
            params, cache, jnp.asarray([tok], jnp.int32),
            jnp.int32(pos), cfg)
        pos += 1
    lane = jax.device_get(cache)
    dims = [int(np.asarray(l).shape[-1]) for l in lane.values()]
    pager = KVPager.for_capacity(
        fast_bytes=512, kv_codec="int8", codec_dtype=cfg.compute_dtype,
        codec_block=math.gcd(*dims), page_bytes=1024)
    pager.park(7, lane)
    assert pager.stats()["kv_bytes_encoded"] > 0, "no page demoted"
    back = pager.fetch(7, like=lane)
    for name in sorted(lane):
        orig = np.asarray(jnp.asarray(lane[name]).astype(jnp.float32))
        got = np.asarray(jnp.asarray(back[name]).astype(jnp.float32))
        ch = orig.shape[-1]
        xf = orig.reshape(-1, ch)
        s = np.abs(xf).max(axis=1, keepdims=True) / 127.0
        bound = np.maximum(s, 1e-12) * 0.5 + 2.0 ** -7 * np.abs(xf) + 1e-5
        assert np.all(np.abs(got.reshape(-1, ch) - xf) <= bound), name
    pager.close()


def test_kv_codec_recorded_in_checkpoint_meta(arch):
    """The paged checkpoint meta carries the kv_codec, so restore can
    refuse a scheduler whose pool layout is incompatible."""
    from repro.serve.scheduler import PagedServeScheduler

    cfg, model, params = arch
    sched = PagedServeScheduler(cfg, model, params, slots=1,
                                max_len=MAX_LEN, page_tokens=PT,
                                kv_codec="int8")
    _, meta = sched._serving_state()
    assert meta["serve"]["paged"]["kv_codec"] == "int8"
