"""Property harness for PrefixCache eviction.

The invariant the pool-resident prefix sharing leans on: eviction only
ever removes zero-reference *leaf* nodes, and an evicted node leaves no
stale payload bytes behind in the tier stack.  Randomized
insert/match/acquire/release sequences check, after every operation:

* every cached node's payload is still present and fetchable (no
  premature delete), every evicted node's payload is gone (no stale
  bytes);
* a node with live stream references is never evicted;
* an interior node is never evicted while it has children;
* ``bytes_cached`` equals the sum of live node sizes and respects the
  capacity budget whenever an unreferenced leaf exists to evict.
"""

import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.memory.stack import TierStack
from repro.memory.tiers import MemoryTier, TierKind, TierSpec
from repro.serve.prefix import LaneLayout, PrefixCache, prefix_page_key

PAGE_TOKENS = 2
MAX_LEN = 8


def tiny_layout() -> LaneLayout:
    template = {"k": np.zeros((2, 1, MAX_LEN, 2, 2), np.float32)}
    axes = {"k": ("layers", "batch", "kv_seq", "heads", "head_dim")}
    return LaneLayout(template, axes)


def make_cache(capacity_pages=4):
    stack = TierStack([("mem", MemoryTier(
        TierSpec(TierKind.DRAM, 10**9, 1e9, 1e9, 1e-6)))])
    layout = tiny_layout()
    probe = PrefixCache(stack, layout, page_tokens=PAGE_TOKENS)
    lane = filled_lane(layout, 0)
    node = probe.extend([1, 2], PAGE_TOKENS, lane)[0]
    page_bytes = node.nbytes
    probe.clear()
    return PrefixCache(stack, layout, page_tokens=PAGE_TOKENS,
                       capacity_bytes=capacity_pages * page_bytes)


def filled_lane(layout, seed):
    """A lane whose KV bytes depend on ``seed`` (payloads must differ)."""
    lane = layout.zero_lane()
    lane["k"][...] = np.arange(lane["k"].size).reshape(lane["k"].shape) + seed
    return lane


PROMPTS = [           # overlapping prefixes -> a real trie, shared nodes
    [1, 2, 3, 4, 5, 6],
    [1, 2, 3, 4, 9, 9],
    [1, 2, 7, 7],
    [5, 5, 5, 5, 5, 5],
    [1, 2, 3, 4, 5, 6, 8, 8],
]


class Harness:
    def __init__(self):
        self.cache = make_cache()
        self.evicted = []
        self.cache.on_evict = self.evicted.append
        self.held = set()          # sids with live references

    def insert(self, pick, sid):
        prompt = PROMPTS[pick % len(PROMPTS)]
        upto = (len(prompt) // PAGE_TOKENS) * PAGE_TOKENS
        lane = filled_lane(self.cache.layout, pick)
        self.cache.extend(prompt, upto, lane, sid=sid)
        self.held.add(sid)

    def match(self, pick):
        prompt = PROMPTS[pick % len(PROMPTS)]
        covered, path = self.cache.match(prompt)
        if path:
            lane = self.cache.layout.zero_lane()
            got = self.cache.fetch_into(path, lane)
            assert got == covered or got == 0 or got < covered

    def release(self, sid):
        self.cache.release_stream(sid)
        self.held.discard(sid)

    def check(self):
        cache = self.cache
        live = {d: cache.node(d) for d in list(cache._nodes)}
        # 1. every live node's payload is fetchable; no stale bytes for
        #    evicted digests
        for digest, node in live.items():
            part = cache.read_node_part(node)       # raises if missing
            assert part["k"].shape == (2, 1, PAGE_TOKENS, 2, 2)
        for digest in self.evicted:
            if digest in live:
                continue        # re-inserted after eviction: fine
            with pytest.raises(KeyError):
                cache.stack.get(prefix_page_key(digest))
        # 2. referenced nodes and interior nodes never evicted
        ref_digests = {d for ds in cache.stream_refs().values() for d in ds}
        for digest in self.evicted:
            assert digest not in ref_digests or digest in live, \
                f"{digest} evicted while referenced"
        # 3. bookkeeping: bytes_cached == sum of live node sizes
        assert cache.stats["bytes_cached"] == sum(
            n.nbytes for n in live.values())

    def check_budget(self):
        """Right after an insert (the only op that sweeps): the budget
        holds unless everything left is referenced or interior."""
        evictable = any(not n.children and n.refs == 0
                        for n in self.cache._nodes.values())
        if evictable:
            assert (self.cache.stats["bytes_cached"]
                    <= self.cache.capacity_bytes)


def run_sequence(ops):
    h = Harness()
    for code, arg in ops:
        if code == 0:
            h.insert(arg, sid=arg % 4)
            h.check_budget()
        elif code == 1:
            h.match(arg)
        elif code == 2:
            h.release(arg % 4)
        h.check()
    # final teardown: release everyone; the trie must become fully
    # evictable and the next insert's sweep respects the budget
    for sid in list(h.held):
        h.release(sid)
    h.insert(0, sid=99)
    h.release(99)
    h.check()


def test_fixed_seed_random_sequences():
    rng = np.random.default_rng(4321)
    for _ in range(30):
        n = int(rng.integers(4, 25))
        ops = [(int(rng.integers(0, 3)), int(rng.integers(0, 10)))
               for _ in range(n)]
        run_sequence(ops)


@given(st.lists(st.tuples(st.integers(min_value=0, max_value=2),
                          st.integers(min_value=0, max_value=9)),
                min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_eviction_only_removes_zero_ref_leaves(ops):
    """Hypothesis property: ANY insert/match/release interleaving keeps
    payload bytes exactly in sync with the trie and never evicts a
    referenced or interior node."""
    run_sequence(ops)


def test_on_evict_fires_exactly_once_per_dropped_node():
    h = Harness()
    h.insert(0, sid=0)
    digests = list(h.cache._nodes)
    h.release(0)
    # shrink the budget to zero and trigger a sweep
    h.cache.capacity_bytes = 0
    h.cache._maybe_evict()
    assert sorted(h.evicted) == sorted(digests)
    assert len(h.cache) == 0
    assert h.cache.stats["bytes_cached"] == 0
