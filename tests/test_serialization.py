"""Checkpoint serialization: roundtrips, partitioning, integrity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips cleanly if absent

from repro.io.serialization import (
    StateBlob,
    deserialize_state,
    fragment_key,
    join_fragments,
    partition_blob,
    serialize_state,
)


def make_state():
    return {
        "w": jnp.arange(777, dtype=jnp.float32).reshape(21, 37),
        "b": jnp.ones((5,), jnp.bfloat16) * 1.5,
        "step": jnp.int32(42),
        "nested": {"m": jnp.zeros((3, 3, 3), jnp.float16)},
    }


def test_roundtrip_exact():
    state = make_state()
    blob = serialize_state(state, step=42)
    back = deserialize_state(blob, state)
    for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(state)[0],
        jax.tree_util.tree_flatten_with_path(back)[0],
    ):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), pa


def test_crc_detects_corruption():
    state = make_state()
    blob = serialize_state(state)
    bad = bytearray(blob.data)
    bad[13] ^= 0xFF
    with pytest.raises(IOError):
        deserialize_state(StateBlob(bytes(bad), blob.manifest), state)


def test_shape_mismatch_detected():
    blob = serialize_state({"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        deserialize_state(blob, {"w": jnp.zeros((2, 8))})


def test_leaf_count_mismatch():
    blob = serialize_state({"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        deserialize_state(blob, {"w": jnp.zeros((4,)), "extra": jnp.zeros((1,))})


@settings(max_examples=30, deadline=None)
@given(
    nbytes=st.integers(min_value=0, max_value=5000),
    n_ranks=st.integers(min_value=1, max_value=33),
)
def test_partition_join_identity(nbytes, n_ranks):
    data = bytes(np.random.default_rng(nbytes).integers(0, 256, nbytes, np.uint8))
    frags = partition_blob(data, n_ranks)
    assert len(frags) == n_ranks
    assert len({len(f) for f in frags}) == 1          # all equal size
    assert len(frags[0]) % 4 == 0                     # word aligned
    assert join_fragments(frags, nbytes) == data


def test_elastic_repartition():
    """A blob partitioned for R ranks re-partitions for R' losslessly."""
    data = np.random.default_rng(1).bytes(10_001)
    via_8 = join_fragments(partition_blob(data, 8), len(data))
    via_3 = join_fragments(partition_blob(via_8, 3), len(data))
    assert via_3 == data


def test_fragment_key_stable():
    assert fragment_key("ckpt", 7, 3) == "ckpt/step00000007/frag00003.bin"
