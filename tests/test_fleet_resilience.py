"""Elastic fleet resilience: failure detection, epoch checkpoints,
stream migration, board batching, shared-domain GC.

Layered like tests/test_fleet.py: pure-python tests for the board and
the shared-tier GC, stub-worker tests for the frontend's failure
detector and recovery protocol (the migration/replay logic is exercised
here at full fidelity — greedy-decode token identity at system scale is
gated by benchmarks/fig13_elastic_fleet.py in CI), and a session-level
save_epoch/load_epoch roundtrip.
"""

import os
import time

import numpy as np
import pytest

from repro.memory.shared import SharedTier
from repro.serve.fleet.board import PrefixBoard, record_kind
from repro.serve.fleet.frontend import FleetFrontend
from repro.serve.fleet.worker import epoch_domain, load_epoch, save_epoch


# --------------------------------------------------------------------------- #
# board: record kinds + bounded batches
# --------------------------------------------------------------------------- #

def _prec(i, **extra):
    return dict({"digest": f"d{i}", "parent": "", "chunk": [i], "end": 1,
                 "nbytes": 4, "crc32": 0}, **extra)


def test_record_kind_defaults_to_prefix():
    assert record_kind(_prec(0)) == "prefix"
    assert record_kind({"kind": "epoch", "worker": "w0"}) == "epoch"


def test_board_batched_poll_exact_cursor(tmp_path):
    """max_records bounds one poll; the cursor advances exactly past
    what was returned, so nothing is skipped or replayed."""
    a, b = PrefixBoard(tmp_path), PrefixBoard(tmp_path)
    a.publish([_prec(i) for i in range(5)])
    a.publish([{"kind": "epoch", "worker": "w0", "pid": 1, "step": 4,
                "t": 0.0}])
    a.publish([_prec(i) for i in range(5, 7)])
    got = b.poll(3)
    assert [r["digest"] for r in got] == ["d0", "d1", "d2"]
    got = b.poll(3)
    assert [r.get("digest") for r in got] == ["d3", "d4", None]
    assert record_kind(got[-1]) == "epoch"
    got = b.poll(3)                      # fewer remaining than the batch
    assert [r["digest"] for r in got] == ["d5", "d6"]
    assert b.poll(3) == []
    # an unbounded poller over the same journal sees the same stream
    assert len(PrefixBoard(tmp_path).poll()) == 8


def test_board_batched_poll_with_torn_tail(tmp_path):
    a, b = PrefixBoard(tmp_path), PrefixBoard(tmp_path)
    a.publish([_prec(i) for i in range(3)])
    with open(a.path, "ab") as f:
        f.write(b'{"digest": "partial')
    assert [r["digest"] for r in b.poll(2)] == ["d0", "d1"]
    assert [r["digest"] for r in b.poll(2)] == ["d2"]
    assert b.poll(2) == []


# --------------------------------------------------------------------------- #
# shared tier: board-age GC
# --------------------------------------------------------------------------- #

def test_gc_reclaims_only_dead_and_old(tmp_path):
    tier = SharedTier(tmp_path / "dom")
    tier.put("a", b"x" * 10)
    tier.put("b", b"y" * 20)
    # our own pid is alive: everything pinned regardless of age
    res = tier.gc(ttl_s=0.0, now=time.time() + 3600)
    assert res["gc_reclaimed"] == 0 and res["gc_pinned_live"] == 2
    # publisher dead but records young: pinned by the TTL window
    res = tier.gc(ttl_s=3600.0, pid_alive=lambda p: False)
    assert res["gc_reclaimed"] == 0 and res["gc_pinned_young"] == 2
    # dead + old: reclaimed, bytes accounted, objects gone
    res = tier.gc(ttl_s=1.0, pid_alive=lambda p: False,
                  now=time.time() + 3600)
    assert res["gc_reclaimed"] == 2
    assert res["gc_reclaimed_bytes"] == 30
    with pytest.raises(KeyError):
        tier.get("a")
    assert tier.used_bytes() == 0
    assert tier.gc_stats["gc_runs"] == 3
    assert tier.gc_stats["gc_reclaimed"] == 2


def test_gc_live_publisher_pins_shared_object(tmp_path):
    """An object with one live publisher among several dead ones stays."""
    tier = SharedTier(tmp_path / "dom")
    tier.put("k", b"z" * 8)
    me = os.getpid()
    res = tier.gc(ttl_s=0.0, pid_alive=lambda p: p == me,
                  now=time.time() + 3600)
    assert res["gc_reclaimed"] == 0 and res["gc_pinned_live"] == 1
    assert tier.get("k") == b"z" * 8


def test_gc_missing_timestamp_counts_as_old(tmp_path):
    """Records from before the timestamp upgrade are infinitely old."""
    from repro.memory.shared import _DomainLock

    tier = SharedTier(tmp_path / "dom")
    tier.put("k", b"q" * 4)
    # strip the timestamp the way a pre-upgrade manifest would look
    with _DomainLock(tier._lock_path):
        m = tier._read_manifest()
        m["k"].pop("t")
        tier._write_manifest(m)
    res = tier.gc(ttl_s=10.0, pid_alive=lambda p: False)
    assert res["gc_reclaimed"] == 1


# --------------------------------------------------------------------------- #
# epoch checkpoints: save/load roundtrip through the shared tier
# --------------------------------------------------------------------------- #

class StubSched:
    def __init__(self, descs):
        self._descs = descs

    def live_descriptors(self):
        return list(self._descs)


def test_epoch_roundtrip(tmp_path):
    from repro.api.session import ResilienceSession

    descs = [
        {"sid": 0, "tokens": [1, 2, 3, 4, 5], "plen": 3,
         "emitted": [4, 5], "max_new": 4, "weight": 2},
        {"sid": 1, "tokens": [7, 8, 9], "plen": 3,
         "emitted": [], "max_new": 6, "weight": 1},
        {"sid": 2, "tokens": [6, 6], "plen": 2,
         "emitted": [], "max_new": 1, "weight": 1},
    ]
    sess = ResilienceSession.for_shared_tier(
        tmp_path, domain=epoch_domain("w7"))
    try:
        # sid 2 has no frontend rid (engine-local stream): excluded
        n = save_epoch(sess, StubSched(descs), {0: 101, 1: 102}, step=9)
        assert n == 2
    finally:
        sess.close()

    ep = load_epoch(tmp_path, "w7")
    assert set(ep) == {101, 102}
    assert ep[101]["prompt"] == [1, 2, 3]
    assert ep[101]["emitted"] == [4, 5]
    # total budget = remaining + already-emitted
    assert ep[101]["max_new_total"] == 6
    assert ep[101]["weight"] == 2 and ep[101]["step"] == 9
    assert ep[102]["prompt"] == [7, 8, 9] and ep[102]["emitted"] == []
    assert ep[102]["max_new_total"] == 6


def test_epoch_last_wins(tmp_path):
    from repro.api.session import ResilienceSession

    sess = ResilienceSession.for_shared_tier(
        tmp_path, domain=epoch_domain("w0"))
    try:
        d = {"sid": 0, "tokens": [1, 2, 3], "plen": 2, "emitted": [3],
             "max_new": 5, "weight": 1}
        save_epoch(sess, StubSched([d]), {0: 42}, step=4)
        d2 = dict(d, tokens=[1, 2, 3, 9], emitted=[3, 9], max_new=4)
        save_epoch(sess, StubSched([d2]), {0: 42}, step=8)
    finally:
        sess.close()
    ep = load_epoch(tmp_path, "w0")
    assert ep[42]["emitted"] == [3, 9] and ep[42]["step"] == 8


def test_load_epoch_missing_is_empty(tmp_path):
    assert load_epoch(tmp_path, "never-started") == {}
    assert load_epoch(tmp_path / "absent", "w0") == {}


# --------------------------------------------------------------------------- #
# failure detector + migration (stub workers — no processes, no jax)
# --------------------------------------------------------------------------- #

class DeadableWorker:
    """WorkerHandle stand-in with a controllable liveness surface: the
    test scripts heartbeats, token emissions, and process death."""

    def __init__(self):
        self.submitted = []
        self._out = []
        self.hb_age = 0.0
        self.is_alive = True

    def submit(self, rid, prompt, max_new, weight=1):
        self.submitted.append({"rid": rid, "prompt": list(prompt),
                               "max_new": int(max_new), "weight": weight})

    def emit(self, rid, tokens):
        self._out.append({"op": "tokens", "rid": rid,
                          "tokens": list(tokens)})

    def emit_done(self, rid, tokens):
        self._out.append({"op": "done", "rid": rid, "tokens": list(tokens)})

    def messages(self):
        out, self._out = self._out, []
        return out

    def heartbeat_age(self):
        return self.hb_age

    def alive(self):
        return self.is_alive

    def stats(self):
        return {}

    def stop(self):
        pass


def test_slow_but_alive_is_suspect_never_dead():
    """The detector's conjunction: heartbeat staleness alone must never
    trigger recovery — only an actually-exited process is dead."""
    w0, w1 = DeadableWorker(), DeadableWorker()
    fe = FleetFrontend([w0, w1], hb_timeout_s=1.0)
    rid = fe.submit([1, 2, 3], 5)
    fe.pump()
    assert fe.assignment(rid) == 0
    w0.emit(rid, [10, 11])
    fe.pump()
    assert fe.progress(rid) == [10, 11]
    # arbitrarily stale heartbeat, process alive: suspect, no migration
    w0.hb_age = 1e9
    for _ in range(3):
        fe.pump()
    assert fe.worker_state(0) == "suspect"
    assert fe.stats["workers_failed"] == 0
    assert fe.assignment(rid) == 0
    assert not w1.submitted
    # the worker comes back: state returns to ok, stream untouched
    w0.hb_age = 0.0
    fe.pump()
    assert fe.worker_state(0) == "ok"


def test_dead_worker_streams_migrate_with_replay():
    w0, w1 = DeadableWorker(), DeadableWorker()
    fe = FleetFrontend([w0, w1], hb_timeout_s=0.5)
    rid = fe.submit([1, 2, 3], 5)
    fe.pump()
    w0.emit(rid, [10, 11])
    fe.pump()
    # SIGKILL equivalent: stale AND exited
    w0.hb_age, w0.is_alive = 10.0, False
    fe.pump()
    assert fe.worker_state(0) == "dead"
    assert fe.stats["workers_failed"] == 1
    assert fe.stats["streams_migrated"] == 1
    assert fe.assignment(rid) == 1
    sub = w1.submitted[0]
    # the streamed prefix replays as prompt suffix; budget shrinks
    assert sub["prompt"] == [1, 2, 3, 10, 11]
    assert sub["max_new"] == 3
    # the survivor reports only its own tokens; the caller sees the
    # merged stream — identical to an uninterrupted run
    w1.emit_done(rid, [12, 13, 14])
    fe.pump()
    assert fe.result(rid) == [10, 11, 12, 13, 14]
    assert fe.stats["completed"] == 1
    assert fe.live_workers() == [1]
    assert fe.worker_stats() == [{}]     # dead worker excluded


def test_recovery_completes_budget_spent_stream():
    """A stream whose whole budget was already streamed back completes
    directly from the recovered prefix — no re-dispatch."""
    w0, w1 = DeadableWorker(), DeadableWorker()
    fe = FleetFrontend([w0, w1], hb_timeout_s=0.5)
    rid = fe.submit([4, 4], 2)
    fe.pump()
    w0.emit(rid, [5, 6])                 # full budget, "done" lost in crash
    fe.pump()
    w0.hb_age, w0.is_alive = 10.0, False
    fe.pump()
    assert fe.result(rid) == [5, 6]
    assert fe.stats["streams_completed_on_recovery"] == 1
    assert not w1.submitted


def test_recovery_prefers_longer_epoch_prefix(tmp_path):
    """The worker's last epoch may be ahead of what reached the
    frontend (the crash ate pipe messages): recovery replays the longer
    prefix — both are prefixes of the same greedy continuation."""
    from types import SimpleNamespace

    from repro.api.session import ResilienceSession

    w0, w1 = DeadableWorker(), DeadableWorker()
    w0.spec = SimpleNamespace(ckpt_every=4, shared_root=str(tmp_path),
                              name="w0")
    fe = FleetFrontend([w0, w1], hb_timeout_s=0.5)
    rid = fe.submit([1, 2], 6)
    fe.pump()
    w0.emit(rid, [30])                   # frontend saw only one token
    fe.pump()
    sess = ResilienceSession.for_shared_tier(
        tmp_path, domain=epoch_domain("w0"))
    try:
        save_epoch(sess, StubSched([
            {"sid": 0, "tokens": [1, 2, 30, 31, 32], "plen": 2,
             "emitted": [30, 31, 32], "max_new": 3, "weight": 1}]),
            {0: rid}, step=4)
    finally:
        sess.close()
    w0.hb_age, w0.is_alive = 10.0, False
    fe.pump()
    sub = w1.submitted[0]
    assert sub["prompt"] == [1, 2, 30, 31, 32] and sub["max_new"] == 3
    w1.emit_done(rid, [33, 34, 35])
    fe.pump()
    assert fe.result(rid) == [30, 31, 32, 33, 34, 35]


def test_dispatch_with_all_workers_dead_raises():
    w0 = DeadableWorker()
    fe = FleetFrontend([w0], hb_timeout_s=0.5)
    rid = fe.submit([1], 3)
    fe.pump()
    w0.emit(rid, [9])
    fe.pump()
    w0.hb_age, w0.is_alive = 10.0, False
    with pytest.raises(RuntimeError, match="no live workers"):
        fe.pump()


def test_stub_without_liveness_surface_is_trusted():
    """Handles that expose no heartbeat/liveness (legacy stubs) are
    never classified — the detector requires both signals."""
    class Plain:
        def __init__(self):
            self.submitted = []

        def submit(self, rid, prompt, max_new, weight=1):
            self.submitted.append(rid)

        def messages(self):
            return []

        def stop(self):
            pass

    fe = FleetFrontend([Plain()], hb_timeout_s=0.0)
    fe.submit([1], 1)
    fe.pump()
    assert fe.worker_state(0) == "ok"
    assert fe.stats["workers_failed"] == 0


# --------------------------------------------------------------------------- #
# unified serving API construction surface (no model build)
# --------------------------------------------------------------------------- #

def test_serve_config_worker_spec_carries_resilience_knobs(tmp_path):
    from repro.serve import ServeConfig

    cfg = ServeConfig(arch="phi3-mini-3.8b", slots=3, max_len=64,
                      page_tokens=8, ckpt_every=6, hb_interval_s=0.07,
                      adopt_batch=32, seed=5)
    spec = cfg.worker_spec(str(tmp_path), name="w9")
    assert spec.name == "w9" and spec.ckpt_every == 6
    assert spec.hb_interval_s == 0.07 and spec.adopt_batch == 32
    assert spec.slots == 3 and spec.max_len == 64
    assert spec.page_tokens == 8 and spec.seed == 5
    assert spec.shared_root == str(tmp_path)


def test_serve_fleet_rejects_zero_workers():
    from repro.serve import Serve, ServeConfig

    with pytest.raises(ValueError):
        Serve.fleet(ServeConfig(), workers=0)


def test_serve_engine_constructor_warns_deprecated():
    import warnings

    import repro.serve.engine as eng

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        old = eng._WARNED_DEPRECATED
        eng._WARNED_DEPRECATED = False
        try:
            with pytest.raises(Exception):
                # cfg=None dies after the warning fires; the warning is
                # what this test pins
                eng.ServeEngine(None, None, None, batch=1, max_len=4)
        finally:
            eng._WARNED_DEPRECATED = old
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
