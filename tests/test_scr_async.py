"""Asynchronous checkpoint drain pipeline: executor, futures, durability.

Drain timing is made deterministic with a gated global tier: fragment
writes block on an Event the test controls, while descriptor writes (the
tiny SCR index records) pass through so save() can complete its
foreground phase.
"""

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.topology import NodeState, VirtualCluster
from repro.core.scr import DrainState, SCRManager, Strategy, _desc_key, _global_key
from repro.memory.tiers import MemoryHierarchy, MemoryTier

STATE = {
    "w": jnp.arange(4000, dtype=jnp.float32).reshape(50, 80),
    "step": jnp.int32(7),
}
TEMPLATE = {
    "w": jnp.zeros((50, 80), jnp.float32),
    "step": jnp.int32(0),
}


class GatedGlobalTier(MemoryTier):
    """Global tier whose checkpoint-fragment writes block on a gate.

    Descriptor traffic (``scr/desc/...``) is never gated, mirroring a real
    system where the tiny index write is cheap but the bulk flush is not.
    """

    def __init__(self, inner: MemoryTier):
        super().__init__(inner.spec, inner.backing_dir)
        self.gate = threading.Event()

    def _maybe_block(self, key: str) -> None:
        if key.startswith("ckpt/"):
            assert self.gate.wait(timeout=30), "test gate never opened"

    def put(self, key, data, streams=1):
        self._maybe_block(key)
        return super().put(key, data, streams=streams)

    def put_stream(self, key, chunks, streams=1):
        self._maybe_block(key)
        return super().put_stream(key, chunks, streams=streams)


def make_async_scr(tmp_path, **kw):
    cl = VirtualCluster(4, 4, root=tmp_path / "run", xor_group_size=4)
    hier = MemoryHierarchy(cl)
    hier.global_tier = GatedGlobalTier(hier.global_tier)
    kw.setdefault("flush_every", 1)
    scr = SCRManager(cl, hier, strategy=Strategy.BUDDY, procs_per_node=2,
                     async_drain=True, **kw)
    return cl, hier, scr


def wipe_all_nvm(cl, hier):
    for r in cl.ranks():
        cl.fail(r, NodeState.FAILED_NODE)
        cl.recover(r)
        hier.invalidate(r)


def assert_state_equal(a, b):
    assert np.asarray(a["w"]).tobytes() == np.asarray(b["w"]).tobytes()


def test_drain_completes_after_save_returns(tmp_path):
    cl, hier, scr = make_async_scr(tmp_path)
    rec = scr.save(1, STATE)   # returns while the flush is gated out
    assert rec.ticket is not None and not rec.ticket.done()
    assert not rec.drained
    assert not hier.global_tier.exists(_global_key(1, 0))
    # descriptor is already durable, but not marked drained yet
    assert hier.global_tier.exists(_desc_key(1))

    hier.global_tier.gate.set()
    scr.wait_drained(step=1)
    assert rec.ticket.state == DrainState.DONE
    assert rec.ticket.background_s > 0.0
    for node in range(cl.size):
        assert hier.global_tier.exists(_global_key(1, node))


def test_wait_drained_blocks_until_global_holds_checkpoint(tmp_path):
    cl, hier, scr = make_async_scr(tmp_path)
    scr.save(2, STATE)
    opened_at = []

    def open_gate():
        time.sleep(0.2)
        opened_at.append(time.perf_counter())
        hier.global_tier.gate.set()

    threading.Thread(target=open_gate, daemon=True).start()
    t0 = time.perf_counter()
    scr.wait_drained()
    waited = time.perf_counter() - t0
    assert waited >= 0.15, "wait_drained returned before the flush could land"
    assert opened_at and time.perf_counter() >= opened_at[0]
    # drained flag was committed only after the flush
    import json
    desc = json.loads(hier.global_tier.get(_desc_key(2)).decode())
    assert desc["drained"] is True
    # and the drained copy alone recovers the state (all NVM wiped)
    wipe_all_nvm(cl, hier)
    restored, step = scr.restore(TEMPLATE)
    assert step == 2
    assert_state_equal(restored, STATE)


def test_restore_after_kill_recovers_last_drained(tmp_path):
    cl, hier, scr = make_async_scr(tmp_path, keep=4)
    hier.global_tier.gate.set()
    scr.save(1, STATE)
    scr.wait_drained(step=1)          # step 1 fully drained

    newer = dict(STATE)
    newer["w"] = STATE["w"] + 1
    hier.global_tier.gate.clear()     # step 2's flush never lands
    rec2 = scr.save(2, newer)
    assert rec2.ticket is not None and not rec2.ticket.done()

    # "kill": the process dies mid-drain; every NVM copy is lost too
    wipe_all_nvm(cl, hier)
    scr2 = SCRManager(cl, MemoryHierarchy(cl), strategy=Strategy.BUDDY,
                      procs_per_node=2, flush_every=1, keep=4)
    restored, step = scr2.restore(TEMPLATE)
    assert step == 1, "must fall back to the last *drained* checkpoint"
    assert_state_equal(restored, STATE)


def test_restore_cancels_queued_drains(tmp_path):
    cl, hier, scr = make_async_scr(tmp_path, keep=6, drain_depth=2)
    hier.global_tier.gate.set()
    scr.save(1, STATE)
    scr.wait_drained()

    hier.global_tier.gate.clear()
    r2 = scr.save(2, STATE)           # drain running, blocked on the gate
    r3 = scr.save(3, STATE)           # drain queued behind it

    done = threading.Event()
    result = {}

    def do_restore():
        result["out"] = scr.restore(TEMPLATE)
        done.set()

    threading.Thread(target=do_restore, daemon=True).start()
    time.sleep(0.1)
    hier.global_tier.gate.set()       # running drain may now finish
    assert done.wait(timeout=30)
    _, step = result["out"]
    assert step in (2, 3)             # NVM intact: newest recoverable wins
    assert r3.ticket.state in (DrainState.CANCELLED, DrainState.DONE)
    if r3.ticket.state == DrainState.CANCELLED:
        import json
        desc = json.loads(hier.global_tier.get(_desc_key(3)).decode())
        assert desc["drained"] is False, "cancelled drain must not claim durability"


def test_backpressure_blocks_when_drains_pile_up(tmp_path):
    cl, hier, scr = make_async_scr(tmp_path, keep=6, drain_depth=1)
    scr.save(1, STATE)                # occupies the single drain slot

    entered = threading.Event()
    finished = threading.Event()

    def second_save():
        entered.set()
        scr.save(2, STATE)            # must block until slot frees
        finished.set()

    threading.Thread(target=second_save, daemon=True).start()
    assert entered.wait(timeout=5)
    # foreground (local writes + redundancy) is fast; only the executor's
    # backpressure can hold this save for this long
    assert not finished.wait(timeout=0.5)
    hier.global_tier.gate.set()
    assert finished.wait(timeout=30)
    scr.wait_drained()


def test_prune_never_deletes_only_drained_copy(tmp_path):
    """keep=1 with an in-flight drain: the previous step's drained copy is
    the only durable one and must survive pruning until a newer commit."""
    cl, hier, scr = make_async_scr(tmp_path, keep=1)
    hier.global_tier.gate.set()
    scr.save(1, STATE)
    scr.wait_drained(step=1)

    newer = dict(STATE)
    newer["w"] = STATE["w"] + 1
    hier.global_tier.gate.clear()      # step 2's flush stays in flight
    scr.save(2, newer)                 # prune must spare step 1

    wipe_all_nvm(cl, hier)             # kill before the drain lands
    scr2 = SCRManager(cl, MemoryHierarchy(cl), strategy=Strategy.BUDDY,
                      procs_per_node=2, flush_every=1, keep=1)
    restored, step = scr2.restore(TEMPLATE)
    assert step == 1
    assert_state_equal(restored, STATE)

    # once a newer drain commits, the old copy is finally pruned
    hier.global_tier.gate.set()
    scr.wait_drained()
    scr.save(3, newer)
    scr.wait_drained()
    assert 1 not in scr.available_steps()


class FailingGlobalTier(MemoryTier):
    """Global tier whose checkpoint-fragment writes fail while armed."""

    def __init__(self, inner: MemoryTier):
        super().__init__(inner.spec, inner.backing_dir)
        self.fail_fragments = True

    def put_stream(self, key, chunks, streams=1):
        if self.fail_fragments and key.startswith("ckpt/"):
            raise IOError("injected drain failure")
        return super().put_stream(key, chunks, streams=streams)


def test_failed_drain_barrier_is_idempotent(tmp_path):
    cl = VirtualCluster(4, 4, root=tmp_path / "run", xor_group_size=4)
    hier = MemoryHierarchy(cl)
    hier.global_tier = FailingGlobalTier(hier.global_tier)
    scr = SCRManager(cl, hier, strategy=Strategy.BUDDY, procs_per_node=2,
                     flush_every=1, async_drain=True)
    scr.save(1, STATE)
    with pytest.raises(IOError):
        scr.wait_drained()
    with pytest.raises(IOError):
        scr.wait_drained()   # barrier must not go clean after one raise
    assert scr.drain_stats["failed"] == 1

    # an observed failure must not poison the next healthy save
    hier.global_tier.fail_fragments = False
    scr.save(2, STATE)
    scr.wait_drained(step=2)

    # restore absorbs the failure; only then is the barrier clean
    restored, step = scr.restore(TEMPLATE)
    assert step == 2
    assert_state_equal(restored, STATE)
    scr.wait_drained()


def test_prune_spares_inflight_drain_when_nothing_drained_yet(tmp_path):
    """keep=1 with NO drained checkpoint at all: pruning must not cancel an
    older step's in-flight drain — it may become the only durable copy."""
    cl, hier, scr = make_async_scr(tmp_path, keep=1, drain_depth=2)
    scr.save(1, STATE)                 # drain blocked on the gate
    scr.save(2, STATE)                 # prune runs with nothing drained yet
    assert 1 in scr.available_steps(), \
        "undrained step with a live drain ticket must survive prune"
    hier.global_tier.gate.set()
    scr.wait_drained()
    # once newer drains committed, the next prune finally removes step 1
    scr.save(3, STATE)
    scr.wait_drained()
    assert 1 not in scr.available_steps()


def test_scr_rejects_non_draining_beeond_domain(tmp_path):
    cl = VirtualCluster(2, 0, root=tmp_path / "run", xor_group_size=2)
    with pytest.raises(ValueError):
        SCRManager(cl, MemoryHierarchy(cl), strategy=Strategy.SINGLE,
                   procs_per_node=1, beeond_mode="local-only")


def test_drain_future_and_stats(tmp_path):
    cl, hier, scr = make_async_scr(tmp_path)
    hier.global_tier.gate.set()
    rec = scr.save(1, STATE)
    assert scr.drain_future(1) is rec.ticket
    assert rec.ticket.result(timeout=30) >= 0.0
    scr.wait_drained()
    assert scr.drain_stats["completed"] == 1
    assert scr.drain_stats["modelled_bg_s"] > 0.0
    assert scr.drain_future(1) is None  # reaped after the barrier
