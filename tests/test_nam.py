"""NAM device: pool management, ring-buffer notifications, near-mem parity."""

import pytest

from repro.core import parity
from repro.core.nam import NAMDevice
from repro.memory.tiers import MemoryTier, TierKind, TierSpec


def make_nam(capacity=2 * 1024**2, ring_slots=4):
    tier = MemoryTier(TierSpec(TierKind.NAM, capacity, 11.5e9, 11.5e9, 1.8e-6,
                               shared=True))
    return NAMDevice(tier, ring_slots=ring_slots)


def test_put_get_roundtrip():
    nam = make_nam()
    nam.alloc("region", 1024)
    nam.put("region", b"x" * 1024)
    assert nam.get("region") == b"x" * 1024


def test_notifications_in_order():
    nam = make_nam()
    nam.alloc("a", 100)
    nam.put("a", b"1")
    nam.get("a")
    n1, n2 = nam.poll(), nam.poll()
    assert (n1.op, n2.op) == ("put", "get")
    assert n1.seq < n2.seq
    assert nam.poll() is None


def test_pool_capacity_enforced():
    nam = make_nam(capacity=1000)
    nam.alloc("a", 800)
    with pytest.raises(MemoryError):
        nam.alloc("b", 400)
    nam.free("a")
    nam.alloc("b", 400)


def test_region_bounds_checked():
    nam = make_nam()
    nam.alloc("r", 10)
    with pytest.raises(ValueError):
        nam.put("r", b"x" * 100)
    with pytest.raises(KeyError):
        nam.put("unalloc", b"x")


def test_offload_parity_matches_host_xor():
    nam = make_nam()
    frags = [bytes([i]) * 4096 for i in range(4)]
    nam.alloc("parity", 4096)
    t = nam.offload_parity("parity", [lambda f=f: f for f in frags], 4096)
    assert t > 0
    assert nam.get("parity") == parity.encode_nam_parity(frags)
    kinds = []
    while (n := nam.poll()) is not None:
        kinds.append(n.op)
    assert "parity" in kinds


def test_transfer_time_shares_links():
    nam = make_nam()
    assert nam.transfer_time(10**6, concurrent=8) > nam.transfer_time(10**6, 1)
