"""Per-arch smoke tests (reduced configs) + family-specific invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, arch_ids, get_config
from repro.configs.shapes import ShapeSpec
from repro.models.registry import get_model, input_specs, make_inputs

SMOKE_SHAPE = ShapeSpec("smoke", 32, 2, "train")

# The per-arch smokes dominate suite wall time (3-22 s each, mostly XLA
# compiles).  The fast lane (-m "not slow") keeps one representative
# dense and one MoE arch; the full matrix runs in the unfiltered suite.
_FAST_ARCHS = {"phi3-mini-3.8b", "qwen2-moe-a2.7b"}


def _smoke_archs():
    return [a if a in _FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
            for a in arch_ids()]


@pytest.mark.parametrize("arch", _smoke_archs())
def test_smoke_forward_and_train_step(arch):
    """Reduced config: forward + one SGD-ish step on CPU, shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    batch = make_inputs(cfg, SMOKE_SHAPE, key)
    logits, aux = model.forward(params, batch, cfg, remat=False)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.padded_vocab
    assert np.isfinite(np.asarray(logits)).all()

    from repro.train.step import make_train_step
    from repro.optim.adamw import AdamWConfig
    from repro.train.step import init_train_state

    state = init_train_state(key, cfg, model)
    step = make_train_step(cfg, model, AdamWConfig(lr=1e-3))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    flat_old = jax.tree_util.tree_leaves(state["params"])
    flat_new = jax.tree_util.tree_leaves(new_state["params"])
    assert any(not np.array_equal(a, b) for a, b in zip(flat_old, flat_new))


@pytest.mark.parametrize("arch", _smoke_archs())
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key, cfg)
    cache = model.init_cache(cfg, 2, 16)
    toks = jnp.zeros((2,), jnp.int32)
    logits, cache = model.decode_step(params, cache, toks, jnp.int32(0), cfg)
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # a second step with updated cache
    logits2, _ = model.decode_step(params, cache, toks + 1, jnp.int32(1), cfg)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize(
    "arch",
    ["phi3-mini-3.8b"] + [pytest.param(a, marks=pytest.mark.slow)
                          for a in ("starcoder2-7b", "rwkv6-3b",
                                    "zamba2-2.7b", "minicpm3-4b")])
def test_decode_matches_forward(arch):
    """Prefill-via-forward logits == step-by-step decode logits."""
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key, cfg)
    t = 10
    toks = jax.random.randint(key, (2, t), 0, cfg.vocab_size, jnp.int32)
    fwd_logits, _ = model.forward(params, {"tokens": toks}, cfg, remat=False)

    cache = model.init_cache(cfg, 2, t + 2)
    dec_logits = []
    for i in range(t):
        lg, cache = model.decode_step(params, cache, toks[:, i], jnp.int32(i), cfg)
        dec_logits.append(lg)
    dec = jnp.stack(dec_logits, axis=1)
    # compare log-softmax over the LOGICAL vocab (padded cols are -inf)
    a = jax.nn.log_softmax(fwd_logits[..., : cfg.vocab_size].astype(jnp.float32), -1)
    b = jax.nn.log_softmax(dec[..., : cfg.vocab_size].astype(jnp.float32), -1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2, rtol=2e-2)


def test_head_padding_is_inert():
    """Padded attention heads must not change the function."""
    cfg = get_config("starcoder2-7b").reduced()          # 4 heads
    model = get_model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key, cfg)
    batch = make_inputs(cfg, SMOKE_SHAPE, key)
    base, _ = model.forward(params, batch, cfg, remat=False)

    import dataclasses
    cfg_pad = dataclasses.replace(cfg, tp=8)             # pads heads 4 -> 8
    assert cfg_pad.padded_heads == 8
    params_pad = model.init(key, cfg_pad)
    # copy the real-head weights in, keep padded slices zero
    dh = cfg.resolved_head_dim
    real = cfg.n_heads * dh
    lp, lpp = params["layers"]["attn"], params_pad["layers"]["attn"]
    lpp["wq"] = lpp["wq"].at[:, :, :real].set(lp["wq"])
    lpp["wq"] = lpp["wq"].at[:, :, real:].set(0.0)
    # MHA: kv heads padded alongside q heads (real kv cols first, pad zero)
    real_kv = cfg.n_kv_heads * dh
    lpp["wk"] = jnp.zeros_like(lpp["wk"]).at[:, :, :real_kv].set(lp["wk"])
    lpp["wv"] = jnp.zeros_like(lpp["wv"]).at[:, :, :real_kv].set(lp["wv"])
    lpp["wo"] = jnp.zeros_like(lpp["wo"]).at[:, :real, :].set(lp["wo"])
    for name in ("ln1", "ln2", "ffn"):
        params_pad["layers"][name] = params["layers"][name]
    params_pad["ln_f"] = params["ln_f"]
    # vocab padding differs (tp 8 vs 1): copy the real rows/cols, padded
    # columns are masked to -inf by lm_logits anyway
    v1 = params["embed"].shape[0]
    params_pad["embed"] = params_pad["embed"].at[:v1].set(params["embed"])
    params_pad["lm_head"] = params_pad["lm_head"].at[:, :v1].set(params["lm_head"])
    padded, _ = model.forward(params_pad, batch, cfg_pad, remat=False)
    np.testing.assert_allclose(
        np.asarray(base[..., : cfg.vocab_size]),
        np.asarray(padded[..., : cfg.vocab_size]),
        atol=2e-3, rtol=2e-3,
    )


def test_moe_capacity_drop_and_aux():
    """MoE: generous capacity matches a naive per-token loop reference."""
    import dataclasses
    from repro.models import moe as M

    cfg = get_config("deepseek-moe-16b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32) * 0.3
    p = {}
    from repro.models.layers import materialize
    p = materialize(key, M.moe_ffn_table(cfg), jnp.float32)
    y, aux = M.moe_ffn(p, x, cfg)

    # naive reference: loop over tokens, run top-k experts densely
    import numpy as onp
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    logits = xc @ p["router"].astype(cd)
    ids, w, _ = M._route(cfg, logits)
    act = jax.nn.silu
    ref = onp.zeros(x.shape, onp.float32)
    for b in range(2):
        for t in range(8):
            for j in range(cfg.moe.top_k):
                e = int(ids[b, t, j])
                h = act(xc[b, t] @ p["wg"][e].astype(cd)) * (xc[b, t] @ p["wu"][e].astype(cd))
                ref[b, t] += float(w[b, t, j]) * onp.asarray(
                    (h @ p["wd"][e].astype(cd)).astype(jnp.float32))
    shared = M._shared_ffn(
        {"wg": p["shared"]["wg"].astype(cd), "wu": p["shared"]["wu"].astype(cd),
         "wd": p["shared"]["wd"].astype(cd)}, xc, cfg)
    ref = ref + onp.asarray(shared.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(y, np.float32), ref, atol=3e-2, rtol=3e-2)
    assert float(aux) > 0


def test_qwen2_padded_experts_unroutable():
    from repro.models import moe as M
    import dataclasses
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    cfg = dataclasses.replace(cfg, tp=16)  # pads 8 -> 16 experts
    assert cfg.padded_experts == 16
    logits = jnp.zeros((1, 4, cfg.padded_experts))
    ids, w, aux = M._route(cfg, logits)
    assert int(jnp.max(ids)) < cfg.moe.n_routed


def test_vocab_padding_masked_in_logits():
    from repro.models.layers import lm_logits
    head = jnp.ones((4, 8))  # padded vocab 8, logical 5
    x = jnp.ones((1, 1, 4))
    logits = lm_logits(x, head, logical_vocab=5, compute_dtype=jnp.float32)
    assert np.all(np.asarray(logits[..., 5:]) < -1e29)
    assert np.all(np.isfinite(np.asarray(logits[..., :5])))


def test_whisper_cross_attention_uses_encoder():
    cfg = get_config("whisper-tiny").reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(5)
    params = model.init(key, cfg)
    toks = jnp.zeros((1, 8), jnp.int32)
    f1 = jax.random.normal(key, (1, cfg.enc_seq, cfg.d_model))
    f2 = f1 + 1.0
    l1, _ = model.forward(params, {"tokens": toks, "enc_frames": f1}, cfg, remat=False)
    l2, _ = model.forward(params, {"tokens": toks, "enc_frames": f2}, cfg, remat=False)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_paligemma_prefix_changes_text_logits():
    cfg = get_config("paligemma-3b").reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(6)
    params = model.init(key, cfg)
    toks = jnp.zeros((1, 8), jnp.int32)
    p1 = jax.random.normal(key, (1, cfg.n_prefix, cfg.d_model))
    l1, _ = model.forward(params, {"tokens": toks, "patches": p1}, cfg, remat=False)
    l2, _ = model.forward(params, {"tokens": toks, "patches": p1 * 2}, cfg, remat=False)
    assert l1.shape[1] == 8  # prefix rows stripped from logits
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_input_specs_cover_all_cells():
    from repro.configs.shapes import shapes_for
    for arch, cfg in REGISTRY.items():
        for shape in shapes_for(cfg.family):
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            for s in jax.tree_util.tree_leaves(specs):
                assert isinstance(s, jax.ShapeDtypeStruct)
