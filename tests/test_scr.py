"""SCR multi-level checkpoint/restart: all five strategies x failures."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.topology import NodeState, VirtualCluster
from repro.core.nam import NAMDevice
from repro.core.scr import SCRManager, Strategy
from repro.memory.tiers import MemoryHierarchy

STATE = {
    "w": jnp.arange(4000, dtype=jnp.float32).reshape(50, 80),
    "m": jnp.ones((17,), jnp.bfloat16),
    "step": jnp.int32(7),
}
TEMPLATE = {
    "w": jnp.zeros((50, 80), jnp.float32),
    "m": jnp.zeros((17,), jnp.bfloat16),
    "step": jnp.int32(0),
}


def make_scr(tmp_path, strategy, **kw):
    cl = VirtualCluster(4, 4, root=tmp_path / "run", xor_group_size=4)
    hier = MemoryHierarchy(cl)
    nam = NAMDevice(hier.nam_tier) if strategy == Strategy.NAM_XOR else None
    scr = SCRManager(cl, hier, nam=nam, strategy=strategy, procs_per_node=2, **kw)
    return cl, hier, scr


def assert_state_equal(a, b):
    assert np.asarray(a["w"]).tobytes() == np.asarray(b["w"]).tobytes()
    assert np.asarray(a["m"]).tobytes() == np.asarray(b["m"]).tobytes()


@pytest.mark.parametrize("strategy", list(Strategy))
def test_save_restore_healthy(tmp_path, strategy):
    cl, hier, scr = make_scr(tmp_path, strategy)
    scr.save(5, STATE)
    restored, step = scr.restore(TEMPLATE)
    assert step == 5
    assert_state_equal(restored, STATE)


@pytest.mark.parametrize(
    "strategy",
    [Strategy.PARTNER, Strategy.BUDDY, Strategy.XOR, Strategy.NAM_XOR],
)
def test_restore_after_node_loss(tmp_path, strategy):
    cl, hier, scr = make_scr(tmp_path, strategy, flush_every=0)
    scr.save(3, STATE)
    cl.fail(2, NodeState.FAILED_NODE)   # NVM content gone
    cl.recover(2)
    hier.invalidate(2)
    restored, step = scr.restore(TEMPLATE)
    assert step == 3
    assert_state_equal(restored, STATE)


def test_single_survives_transient_only(tmp_path):
    cl, hier, scr = make_scr(tmp_path, Strategy.SINGLE, flush_every=0)
    scr.save(1, STATE)
    cl.fail(2, NodeState.FAILED_TRANSIENT)
    cl.recover(2)
    hier.invalidate(2)
    restored, _ = scr.restore(TEMPLATE)
    assert_state_equal(restored, STATE)
    # node loss is NOT survivable without redundancy or a drained copy
    cl.fail(3, NodeState.FAILED_NODE)
    cl.recover(3)
    hier.invalidate(3)
    with pytest.raises(IOError):
        scr.restore(TEMPLATE)


def test_single_falls_back_to_drained_global(tmp_path):
    cl, hier, scr = make_scr(tmp_path, Strategy.SINGLE, flush_every=1)
    scr.save(1, STATE)   # flushed to global storage via BeeOND level
    cl.fail(3, NodeState.FAILED_NODE)
    cl.recover(3)
    hier.invalidate(3)
    restored, _ = scr.restore(TEMPLATE)
    assert_state_equal(restored, STATE)


def test_xor_double_failure_same_group_unrecoverable(tmp_path):
    cl, hier, scr = make_scr(tmp_path, Strategy.XOR, flush_every=0)
    scr.save(1, STATE)
    for r in (0, 1):  # two members of the same XOR group
        cl.fail(r, NodeState.FAILED_NODE)
        cl.recover(r)
        hier.invalidate(r)
    with pytest.raises(IOError):
        scr.restore(TEMPLATE)


def test_xor_double_failure_different_groups_ok(tmp_path):
    cl, hier, scr = make_scr(tmp_path, Strategy.XOR, flush_every=0)
    scr.save(1, STATE)
    for r in (0, 4):  # different groups (cluster / booster)
        cl.fail(r, NodeState.FAILED_NODE)
        cl.recover(r)
        hier.invalidate(r)
    restored, _ = scr.restore(TEMPLATE)
    assert_state_equal(restored, STATE)


def test_restore_picks_newest_recoverable(tmp_path):
    cl, hier, scr = make_scr(tmp_path, Strategy.BUDDY, keep=3)
    scr.save(1, STATE)
    new_state = dict(STATE)
    new_state["w"] = STATE["w"] + 1
    scr.save(2, new_state)
    restored, step = scr.restore(TEMPLATE)
    assert step == 2
    assert np.allclose(np.asarray(restored["w"]), np.asarray(STATE["w"]) + 1)


def test_prune_keeps_latest_k(tmp_path):
    cl, hier, scr = make_scr(tmp_path, Strategy.BUDDY, keep=2)
    for s in range(1, 6):
        scr.save(s, STATE)
    assert scr.available_steps() == [4, 5]


def test_rebuild_restores_local_copy(tmp_path):
    cl, hier, scr = make_scr(tmp_path, Strategy.XOR, flush_every=0)
    scr.save(1, STATE)
    cl.fail(2, NodeState.FAILED_NODE)
    cl.recover(2)
    hier.invalidate(2)
    scr.restore(TEMPLATE, rebuild=True)
    # second restore must now read node 2's fragment locally
    restored, _ = scr.restore(TEMPLATE)
    assert_state_equal(restored, STATE)


def test_async_redundancy_overlaps(tmp_path):
    cl, hier, scr = make_scr(tmp_path, Strategy.BUDDY, async_redundancy=True)
    rec = scr.save(1, STATE)
    scr.wait()
    cl.fail(1, NodeState.FAILED_NODE)
    cl.recover(1)
    hier.invalidate(1)
    restored, _ = scr.restore(TEMPLATE)
    assert_state_equal(restored, STATE)


def test_elastic_restore_onto_resized_cluster(tmp_path):
    """Checkpoint taken on 8 nodes restores on a 12-node cluster."""
    cl, hier, scr = make_scr(tmp_path, Strategy.BUDDY)
    scr.save(4, STATE)
    big = cl.resize(8, 4)
    hier2 = MemoryHierarchy(big)
    scr2 = SCRManager(big, hier2, strategy=Strategy.BUDDY, procs_per_node=2)
    restored, step = scr2.restore(TEMPLATE)
    assert step == 4
    assert_state_equal(restored, STATE)


def test_modelled_strategy_ordering(tmp_path):
    """Fig 4 ordering: PARTNER > XOR > BUDDY > NAM_XOR foreground cost."""
    times = {}
    big_state = {"w": jnp.arange(200_000, dtype=jnp.float32)}
    for strategy in [Strategy.PARTNER, Strategy.BUDDY, Strategy.XOR, Strategy.NAM_XOR]:
        cl, hier, scr = make_scr(tmp_path / strategy.value, strategy, flush_every=0)
        times[strategy] = scr.save(1, big_state).foreground_s
    assert times[Strategy.BUDDY] < times[Strategy.PARTNER]
    assert times[Strategy.NAM_XOR] < times[Strategy.XOR]
