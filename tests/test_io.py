"""SION containers, BeeOND cache semantics, tier capacity/perf model."""

import threading
import time

import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips cleanly if absent

from repro.io.beeond import CacheFS
from repro.io.sion import SionContainer
from repro.memory.tiers import (
    CapacityError,
    DEEPER_TIERS,
    MemoryTier,
    TierKind,
    TierSpec,
)


def mem_tier(capacity=10**9, **kw):
    spec = TierSpec(TierKind.DRAM, capacity, 1e9, 1e9, 1e-6, **kw)
    return MemoryTier(spec)


# ---------------------------------------------------------------------- #
# SION
# ---------------------------------------------------------------------- #


@settings(max_examples=20, deadline=None)
@given(
    chunks=st.lists(
        st.tuples(st.integers(0, 15), st.binary(min_size=0, max_size=512)),
        min_size=1, max_size=12,
    ),
    align=st.sampled_from([1, 64, 4096]),
)
def test_sion_roundtrip(chunks, align):
    c = SionContainer(align=align)
    for i, (rank, data) in enumerate(chunks):
        c.write_chunk(rank, f"chunk{i}", data)
    blob = c.seal()
    c2 = SionContainer.from_bytes(blob)
    for i, (rank, data) in enumerate(chunks):
        assert c2.read_chunk(rank, f"chunk{i}") == data


def test_sion_alignment():
    c = SionContainer(align=4096)
    c.write_chunk(0, "a", b"x" * 10)
    c.write_chunk(1, "b", b"y" * 10)
    c.seal()
    offsets = [e["offset"] for e in c._require_index()]
    assert all(o % 4096 == 0 for o in offsets)


def test_sion_store_open(tmp_path):
    tier = MemoryTier(TierSpec(TierKind.NVM, 10**9, 1e9, 1e9, 1e-6), tmp_path)
    c = SionContainer()
    c.write_chunk(3, "data", b"hello world")
    c.store(tier, "test.sion")
    c2 = SionContainer.open(tier, "test.sion")
    assert c2.read_rank(3) == {"data": b"hello world"}
    assert c2.chunks() == [(3, "data")]


def test_sion_rejects_garbage():
    with pytest.raises(IOError):
        SionContainer.from_bytes(b"NOTSION" + b"\x00" * 100)


def test_sion_seal_freezes():
    c = SionContainer()
    c.write_chunk(0, "a", b"x")
    c.seal()
    with pytest.raises(RuntimeError):
        c.write_chunk(1, "b", b"y")
    with pytest.raises(RuntimeError):
        c.write_chunk_stream(1, "b", [b"y"])


def test_sion_write_chunk_stream_matches_write_chunk():
    c1, c2 = SionContainer(align=64), SionContainer(align=64)
    c1.write_chunk_stream(0, "a", [b"he", b"llo", b""])
    c1.write_chunk(1, "b", b"world")
    c2.write_chunk(0, "a", b"hello")
    c2.write_chunk(1, "b", b"world")
    assert c1.seal() == c2.seal()
    back = SionContainer.from_bytes(c1.seal())
    assert back.read_chunk(0, "a") == b"hello"
    assert back.read_chunk(1, "b") == b"world"


def test_sion_store_stream_roundtrip(tmp_path):
    tier = MemoryTier(TierSpec(TierKind.NVM, 10**9, 1e9, 1e9, 1e-6), tmp_path)
    c = SionContainer()
    c.write_chunk_stream(2, "data", [b"str", b"eamed"])
    c.store_stream(tier, "s.sion")
    assert SionContainer.open(tier, "s.sion").read_chunk(2, "data") == b"streamed"


# ---------------------------------------------------------------------- #
# BeeOND cache
# ---------------------------------------------------------------------- #


def test_cache_sync_writes_through():
    local, glob = mem_tier(), mem_tier()
    fs = CacheFS(local, glob, mode="sync")
    fs.put("k", b"data")
    assert local.get("k") == b"data" and glob.get("k") == b"data"


def test_cache_async_drains():
    local, glob = mem_tier(), mem_tier()
    fs = CacheFS(local, glob, mode="async")
    for i in range(20):
        fs.put(f"k{i}", bytes([i]) * 100)
    fs.flush()
    for i in range(20):
        assert glob.get(f"k{i}") == bytes([i]) * 100
    fs.close()


def test_cache_local_only_never_touches_global():
    local, glob = mem_tier(), mem_tier()
    fs = CacheFS(local, glob, mode="local-only")
    fs.put("k", b"data")
    assert local.exists("k") and not glob.exists("k")


def test_cache_read_through_fills():
    local, glob = mem_tier(), mem_tier()
    glob.put("cold", b"from-global")
    fs = CacheFS(local, glob, mode="sync")
    assert fs.get("cold") == b"from-global"
    assert local.exists("cold")  # cache filled


def test_cache_put_stream_sync_and_async():
    local, glob = mem_tier(), mem_tier()
    fs = CacheFS(local, glob, mode="sync")
    fs.put_stream("k", iter([b"ab", b"cd"]))
    assert local.get("k") == b"abcd" and glob.get("k") == b"abcd"

    fs2 = CacheFS(mem_tier(), mem_tier(), mode="async")
    fs2.put_stream("k", [b"ab", b"cd"])
    fs2.flush()
    assert fs2.global_tier.get("k") == b"abcd"
    fs2.close()


def test_tier_put_stream_capacity_leaves_no_torn_value(tmp_path):
    tier = MemoryTier(TierSpec(TierKind.NVM, 100, 1e9, 1e9, 0), tmp_path)
    with pytest.raises(CapacityError):
        tier.put_stream("big", [b"x" * 60, b"y" * 60])
    assert not tier.exists("big")


def test_cache_async_faster_foreground_than_sync():
    """The BeeOND argument: async put hides the global-tier latency."""
    slow_global = MemoryTier(TierSpec(TierKind.GLOBAL, 10**9, 1e6, 1e6, 1e-3,
                                      shared=True))
    t_sync = CacheFS(mem_tier(), slow_global, mode="sync").put("a", b"x" * 10000)
    t_async = CacheFS(mem_tier(), mem_tier(), mode="async").put("a", b"x" * 10000)
    assert t_async < t_sync


# ---------------------------------------------------------------------- #
# tiers
# ---------------------------------------------------------------------- #


def test_tier_capacity_enforced():
    tier = mem_tier(capacity=100)
    with pytest.raises(CapacityError):
        tier.put("big", b"x" * 200)


def test_shared_tier_divides_bandwidth():
    spec = DEEPER_TIERS[TierKind.GLOBAL]
    assert spec.write_time(10**9, streams=16) > 10 * spec.write_time(10**9, streams=1)


def test_local_tier_constant_bandwidth():
    spec = DEEPER_TIERS[TierKind.NVM]
    assert spec.write_time(10**8, streams=16) == spec.write_time(10**8, streams=1)


def test_tier_delete_and_keys(tmp_path):
    tier = MemoryTier(TierSpec(TierKind.NVM, 10**9, 1e9, 1e9, 0), tmp_path)
    tier.put("a/b.bin", b"1")
    tier.put("a/c.bin", b"2")
    assert list(tier.keys()) == ["a/b.bin", "a/c.bin"]
    tier.delete("a/b.bin")
    assert list(tier.keys()) == ["a/c.bin"]
