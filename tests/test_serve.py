"""ServeEngine: batched decode + serving-state checkpoint/restore."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.topology import NodeState, VirtualCluster
from repro.configs import get_config
from repro.core.scr import SCRManager, Strategy
from repro.memory.tiers import MemoryHierarchy
from repro.models.registry import get_model
from repro.serve.engine import ServeEngine


def test_serve_checkpoint_resume_byte_identical(tmp_path):
    cfg = get_config("phi3-mini-3.8b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)

    cluster = VirtualCluster(4, 0, root=tmp_path)
    hierarchy = MemoryHierarchy(cluster)
    scr = SCRManager(cluster, hierarchy, strategy=Strategy.XOR, procs_per_node=2)

    eng = ServeEngine(cfg, model, params, batch=2, max_len=48, scr=scr)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                cfg.vocab_size, jnp.int32)
    eng.prefill(prompt)
    eng.decode(6)
    eng.save()
    ref = eng.decode(8)  # reference continuation

    # node loss, then a fresh engine restores the serving state
    cluster.fail(1, NodeState.FAILED_NODE)
    cluster.recover(1)
    hierarchy.invalidate(1)
    eng2 = ServeEngine(cfg, model, params, batch=2, max_len=48, scr=scr)
    eng2.restore()
    out = eng2.decode(8)
    assert len(out) == len(ref)
    for a, b in zip(ref, out):
        assert np.array_equal(a, b)


def test_serve_respects_max_len():
    cfg = get_config("rwkv6-3b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, model, params, batch=1, max_len=8)
    eng.prefill(jnp.zeros((1, 4), jnp.int32))
    out = eng.decode(100)
    assert len(out) == 4  # clipped at max_len
