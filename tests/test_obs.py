"""Observability layer: quantile sketches, registry, tracer, flight
recorder.

Layered like the rest of the suite: pure-python unit tests for the
sketch math (bias bounds vs numpy, exact mergeability), the registry
snapshot/merge protocol, the StatsView legacy shim (including the real
TierStack/KVPager wiring), the tracer's record/export surface, and the
flight recorder's append-only crash semantics through a real
SharedTier; one slow end-to-end test SIGKILLs a real worker mid-decode
and reconstructs its last-seconds timeline from the shared domain.
"""

import json
import time
from pathlib import Path

import numpy as np
import pytest

from repro.memory.shared import SharedTier
from repro.obs.metrics import (
    QuantileSketch,
    Registry,
    StatsView,
    merge_snapshots,
    quantile,
)
from repro.obs.recorder import FlightRecorder, flight_key, read_flight
from repro.obs.trace import Tracer, default_tracer, set_default_tracer


# --------------------------------------------------------------------------- #
# quantile sketch: bias bound vs numpy, exact merge
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("q", [0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 1.0])
def test_sketch_quantile_within_alpha_of_numpy(q):
    """The DDSketch contract: the estimate is within relative error
    ``alpha`` of an actual observed value at that rank."""
    rng = np.random.default_rng(0)
    # latency-shaped: lognormal spanning ~4 orders of magnitude
    values = rng.lognormal(mean=-7.0, sigma=2.0, size=4000)
    alpha = 0.01
    sk = QuantileSketch(alpha=alpha)
    for v in values:
        sk.observe(float(v))
    est = sk.quantile(q)
    s = np.sort(values)
    rank = q * (len(s) - 1)
    lo, hi = s[int(np.floor(rank))], s[int(np.ceil(rank))]
    assert lo * (1 - alpha) - 1e-12 <= est <= hi * (1 + alpha) + 1e-12


def test_quantile_helper_matches_numpy_within_alpha():
    rng = np.random.default_rng(1)
    values = rng.uniform(0.5, 100.0, size=2000).tolist()
    for q in (0.5, 0.95, 0.99):
        est = quantile(values, q)
        exact = float(np.quantile(values, q))
        assert abs(est - exact) <= 0.02 * exact
    assert quantile([], 0.99) == 0.0
    assert quantile([3.0], 0.5) == pytest.approx(3.0, rel=0.01)


def test_sketch_handles_negatives_and_zeros():
    sk = QuantileSketch()
    for v in [-4.0, -2.0, 0.0, 0.0, 1.0, 3.0]:
        sk.observe(v)
    assert sk.quantile(0.0) == pytest.approx(-4.0, rel=0.02)
    assert sk.quantile(1.0) == pytest.approx(3.0, rel=0.02)
    assert -2.1 <= sk.quantile(0.25) <= 0.0
    assert sk.count == 6
    assert sk.mean == pytest.approx(-2.0 / 6.0)


def test_sketch_merge_is_exactly_sketch_of_whole():
    """merge(a, b) must equal the sketch built over the concatenated
    stream — bucket-for-bucket, so every quantile answer is identical.
    This is what makes fleet-merged percentiles principled (vs averaging
    per-worker p99s, which has no such guarantee)."""
    rng = np.random.default_rng(2)
    xs = rng.lognormal(size=500)
    ys = rng.lognormal(size=700)
    a, b, whole = QuantileSketch(), QuantileSketch(), QuantileSketch()
    for v in xs:
        a.observe(float(v))
        whole.observe(float(v))
    for v in ys:
        b.observe(float(v))
        whole.observe(float(v))
    a.merge(b)
    assert a.count == whole.count
    assert a.pos == whole.pos
    da, dw = a.to_dict(), whole.to_dict()
    # summation order differs in the last float bits; buckets are exact
    assert da.pop("sum") == pytest.approx(dw.pop("sum"))
    assert da == dw
    for q in (0.01, 0.5, 0.99):
        assert a.quantile(q) == whole.quantile(q)


def test_sketch_dict_roundtrip_and_merge_guard():
    sk = QuantileSketch()
    for v in (0.001, 0.002, 0.5, -1.0, 0.0):
        sk.observe(v)
    d = sk.to_dict()
    assert d["kind"] == "qsketch" and d["count"] == 5
    back = QuantileSketch.from_dict(d)
    assert back.to_dict() == d
    assert back.quantile(0.99) == sk.quantile(0.99)
    # JSON-able end to end (it rides pipes and BENCH artifacts)
    assert QuantileSketch.from_dict(json.loads(json.dumps(d))).count == 5
    with pytest.raises(ValueError):
        sk.merge(QuantileSketch(alpha=0.05))
    with pytest.raises(ValueError):
        QuantileSketch.from_dict({"kind": "nope"})


# --------------------------------------------------------------------------- #
# registry: snapshot shape, merge semantics
# --------------------------------------------------------------------------- #

def test_registry_get_or_create_and_snapshot_nesting():
    reg = Registry()
    c = reg.counter("tier.hits_fast")
    c.inc()
    c.inc(2)
    assert reg.counter("tier.hits_fast") is c
    reg.gauge("worker.cpu_s").set(1.5)
    reg.histogram("frontend.admission_latency_s", tenant="quiet").observe(0.01)
    reg.histogram("frontend.admission_latency_s", tenant="noisy").observe(0.5)
    snap = reg.snapshot()
    assert snap["counters"]["tier"]["hits_fast"] == 3
    assert snap["gauges"]["worker"]["cpu_s"] == 1.5
    hs = snap["histograms"]["frontend"]["admission_latency_s"]
    assert set(hs) == {"tenant=quiet", "tenant=noisy"}
    assert hs["tenant=quiet"]["kind"] == "qsketch"
    assert hs["tenant=quiet"]["count"] == 1
    # snapshots must survive the pipe protocol
    json.dumps(snap)


def test_merge_snapshots_sums_counters_and_merges_sketches():
    a, b = Registry(), Registry()
    a.counter("sched.steps").inc(10)
    b.counter("sched.steps").inc(5)
    b.counter("sched.parks").inc(1)
    for v in (0.001, 0.002):
        a.histogram("frontend.lat", tenant="t").observe(v)
    for v in (0.4, 0.5):
        b.histogram("frontend.lat", tenant="t").observe(v)
    merged = merge_snapshots([a.snapshot(), b.snapshot()])
    assert merged["counters"]["sched"]["steps"] == 15
    assert merged["counters"]["sched"]["parks"] == 1
    sk = merged["histograms"]["frontend"]["lat"]["tenant=t"]
    assert sk["count"] == 4
    # the merged view sees the union — its upper quantiles sit in b's
    # range, far above anything a observed
    back = QuantileSketch.from_dict(sk)
    assert back.quantile(1.0) == pytest.approx(0.5, rel=0.02)
    assert back.quantile(0.99) >= 0.4 * (1 - 0.011)
    assert merge_snapshots([]) == {}


# --------------------------------------------------------------------------- #
# StatsView: every legacy stats idiom, backed by registry counters
# --------------------------------------------------------------------------- #

def test_statsview_keeps_legacy_dict_idioms():
    reg = Registry()
    stats = StatsView(reg, "sched", {"steps": 0, "parks": 0})
    stats["steps"] += 3                      # in-place bump
    stats["parks"] = 2                       # assignment
    stats.setdefault("spills", 0)            # lazy key
    stats.update({"resumes": 1})             # bulk
    assert stats["steps"] == 3 and len(stats) == 4
    assert dict(stats) == {"steps": 3, "parks": 2, "spills": 0,
                           "resumes": 1}
    assert stats() == dict(stats)            # TierStack's callable form
    # the same numbers are registry counters, fleet-mergeable
    snap = reg.snapshot()
    assert snap["counters"]["sched"] == dict(stats)
    with pytest.raises(KeyError):
        stats["absent"]
    del stats["spills"]
    assert "spills" not in reg.snapshot()["counters"]["sched"]
    assert int(stats["steps"]) == 3          # integer-valued stays int-y


def test_kvpager_and_tierstack_share_one_registry():
    """The real wiring: pager counters and tier counters land in one
    registry, so one snapshot covers the whole KV path and every
    pre-obs stats key still resolves."""
    from repro.serve.kvpage import KVPager

    pager = KVPager.for_capacity(fast_bytes=1 << 20, paged=True,
                                 page_bytes=4096)
    try:
        assert pager.registry is pager.stack.registry
        legacy = pager.stack.stats()         # the pre-obs callable form
        assert "hits_fast" in legacy or "hits_hbm" in legacy
        snap = pager.registry.snapshot()
        assert set(legacy) <= set(snap["counters"]["tier"])
        assert "kv_pages_put" in snap["counters"]["kv"]
    finally:
        pager.close()


def test_frontend_stats_and_admission_latency_from_registry():
    from repro.serve.fleet.frontend import FleetFrontend

    class Plain:
        def __init__(self):
            self.submitted = []

        def submit(self, rid, prompt, max_new, weight=1):
            self.submitted.append(rid)

        def messages(self):
            return []

        def stop(self):
            pass

    fe = FleetFrontend([Plain()])
    rid = fe.submit([1, 2, 3], 4, tenant="quiet")
    fe.pump()
    assert fe.stats["submitted"] == 1 and fe.stats["dispatched"] == 1
    snap = fe.registry.snapshot()
    assert snap["counters"]["frontend"]["dispatched"] == 1
    h = snap["histograms"]["frontend"]["admission_latency_s"]
    assert h["tenant=quiet"]["count"] == 1
    assert fe.admission_latency_p99("quiet") >= 0.0
    assert fe.admission_latency_p99("never-dispatched") == 0.0
    assert rid in fe._requests


def test_fleet_stats_merges_worker_snapshots():
    from repro.serve.fleet.frontend import FleetFrontend

    def worker_snap(steps, lat):
        reg = Registry()
        reg.counter("sched.steps").inc(steps)
        reg.histogram("frontend.lat").observe(lat)
        return reg.snapshot()

    class SnapWorker:
        def __init__(self, name, snap):
            from types import SimpleNamespace
            self.spec = SimpleNamespace(name=name)
            self._snap = snap

        def submit(self, *a, **k):
            pass

        def messages(self):
            return []

        def stats(self):
            return {"registry": self._snap}

        def stop(self):
            pass

    fe = FleetFrontend([SnapWorker("w0", worker_snap(7, 0.001)),
                        SnapWorker("w1", worker_snap(5, 0.9))])
    obs = fe.fleet_stats()
    assert set(obs["workers"]) == {"w0", "w1"}
    assert obs["merged"]["counters"]["sched"]["steps"] == 12
    sk = obs["merged"]["histograms"]["frontend"]["lat"]
    assert sk["count"] == 2
    # frontend's own counters ride the same merge
    assert obs["merged"]["counters"]["frontend"]["submitted"] == 0


# --------------------------------------------------------------------------- #
# tracer: spans, events, ring bound, export, disabled no-op
# --------------------------------------------------------------------------- #

def test_tracer_span_event_records():
    tr = Tracer(process="t0")
    with tr.span("prefill", tid=3, tokens=16):
        pass
    sp = tr.begin("fetch", tid=1)
    tr.end(sp, bytes_moved=512)
    tr.event("finish", tid=3, emitted=4)
    recs = tr.records()
    assert [r["name"] for r in recs] == ["prefill", "fetch", "finish"]
    prefill, fetch, finish = recs
    assert prefill["ph"] == "X" and prefill["dur"] >= 0.0
    assert prefill["args"] == {"tokens": 16} and prefill["tid"] == 3
    # end() args merge into begin() args
    assert fetch["args"] == {"bytes_moved": 512}
    assert finish["ph"] == "i"
    assert tr.records("finish") == [finish]
    assert len(tr) == 3
    tr.clear()
    assert tr.records() == []


def test_tracer_disabled_is_noop_and_none_safe():
    tr = Tracer(enabled=False)
    with tr.span("prefill", tid=0):
        pass
    sp = tr.begin("step")
    assert sp is None
    tr.end(sp)                               # None handle accepted
    tr.end(None, extra=1)
    tr.event("finish")
    assert len(tr) == 0


def test_tracer_ring_bounded_drop_oldest():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.event(f"e{i}")
    assert [r["name"] for r in tr.records()] == ["e6", "e7", "e8", "e9"]


def test_tracer_sink_receives_every_completed_record():
    rec = FlightRecorder("w0")
    tr = Tracer(sink=rec)
    with tr.span("step"):
        pass
    tr.event("finish")
    assert rec.pending() == 2


def test_chrome_trace_export(tmp_path):
    tr = Tracer(process="w0")
    with tr.span("prefill", tid=2, tokens=8):
        pass
    tr.event("finish", tid=2)
    doc = tr.chrome_trace()
    evs = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert meta[0]["args"]["name"] == "w0"
    assert evs[0]["ph"] == "X" and evs[0]["dur"] >= 0.0
    assert evs[0]["ts"] == pytest.approx(tr.records()[0]["ts"] * 1e6)
    assert evs[1]["ph"] == "i"
    # foreign records (a flight-recorder read-back) group by proc tag
    foreign = [{"name": "step", "ph": "X", "ts": 1.0, "dur": 0.1,
                "tid": 0, "proc": "wA"},
               {"name": "step", "ph": "X", "ts": 1.1, "dur": 0.1,
                "tid": 0, "proc": "wB"}]
    doc2 = tr.chrome_trace(foreign)
    pids = {e["pid"] for e in doc2["traceEvents"] if e["ph"] != "M"}
    assert len(pids) == 2
    out = tmp_path / "trace.json"
    tr.export(out)
    assert json.loads(out.read_text())["traceEvents"]


def test_default_tracer_swap():
    prev = set_default_tracer(Tracer(process="test"))
    try:
        assert default_tracer().process == "test"
    finally:
        set_default_tracer(prev)


# --------------------------------------------------------------------------- #
# flight recorder: bounded buffer, append-only flush, torn-tail read
# --------------------------------------------------------------------------- #

def test_recorder_bounded_pending_drops_oldest():
    rec = FlightRecorder("w0", capacity=3)
    for i in range(5):
        rec.record({"name": f"e{i}", "ph": "i", "ts": float(i)})
    assert rec.pending() == 3 and rec.dropped == 2


def test_recorder_flush_and_read_roundtrip(tmp_path):
    tier = SharedTier(tmp_path / "dom")
    rec = FlightRecorder("w3")
    rec.record({"name": "step", "ph": "X", "ts": 1.0, "dur": 0.1, "tid": 0})
    rec.record({"name": "finish", "ph": "i", "ts": 1.2, "tid": 4})
    assert rec.flush(tier) == 2
    assert rec.pending() == 0 and rec.flushed == 2
    assert rec.flush(tier) == 0              # nothing pending: no write
    rec.record({"name": "park", "ph": "i", "ts": 1.3, "tid": 4})
    rec.flush(tier)                          # second flush appends
    records, torn = read_flight(tier, "w3")
    assert torn == 0
    assert [r["name"] for r in records] == ["step", "finish", "park"]
    assert all(r["proc"] == "w3" for r in records)
    # last=N tails the timeline
    tail, _ = read_flight(tier, "w3", last=2)
    assert [r["name"] for r in tail] == ["finish", "park"]
    # a worker that never flushed reads as empty, not an error
    assert read_flight(tier, "never") == ([], 0)


def test_read_flight_tolerates_torn_tail(tmp_path):
    """A SIGKILL mid-append tears at most the final record; every line
    before it is intact because the journal is append-only."""
    tier = SharedTier(tmp_path / "dom")
    rec = FlightRecorder("w9")
    for i in range(3):
        rec.record({"name": f"e{i}", "ph": "i", "ts": float(i)})
    rec.flush(tier)
    # the kill: a half-written final record
    tier.append(flight_key("w9"), b'{"name":"e3","ph":"X","ts":3')
    records, torn = read_flight(tier, "w9")
    assert torn == 1
    assert [r["name"] for r in records] == ["e0", "e1", "e2"]


def test_recorder_failed_flush_keeps_pending():
    class Refusing:
        def append(self, key, data):
            raise OSError("shared domain unreachable")

    rec = FlightRecorder("w0")
    rec.record({"name": "step", "ph": "i", "ts": 0.0})
    with pytest.raises(OSError):
        rec.flush(Refusing())
    assert rec.pending() == 1                # buffer intact for retry


# --------------------------------------------------------------------------- #
# check_regression: metric paths resolve through sketch leaves
# --------------------------------------------------------------------------- #

def _load_check_regression():
    import importlib.util

    path = Path(__file__).resolve().parent.parent / "benchmarks" \
        / "check_regression.py"
    spec = importlib.util.spec_from_file_location("_check_regression", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_regression_resolves_sketch_stats():
    cr = _load_check_regression()
    sk = QuantileSketch()
    for v in (0.001, 0.002, 0.003, 0.8):
        sk.observe(v)
    doc = {"registry": {"merged": {"frontend": {
        "admission_latency_s": {"tenant=quiet": sk.to_dict()}}}}}
    base = "registry.merged.frontend.admission_latency_s.tenant=quiet"
    assert cr._get(doc, base + ".p99") == pytest.approx(
        sk.quantile(0.99), rel=1e-9)
    # pNN beyond the precomputed fields re-hydrates the sketch
    assert cr._get(doc, base + ".p75") == pytest.approx(
        sk.quantile(0.75), rel=1e-9)
    assert cr._get(doc, base + ".count") == 4
    assert cr._get(doc, base + ".mean") == pytest.approx(sk.mean)
    assert cr._get(doc, base + ".nope") is None
    assert cr._get(doc, "registry.merged.frontend.absent.p99") is None


# --------------------------------------------------------------------------- #
# slow: the black box survives a SIGKILL'd real worker
# --------------------------------------------------------------------------- #

@pytest.mark.slow
def test_flight_recorder_survives_worker_sigkill(tmp_path):
    """Kill a real worker mid-decode; the frontend reconstructs its
    last-seconds span timeline from the shared-domain journal — the
    observability acceptance criterion."""
    from repro.serve.fleet import FleetFrontend, WorkerSpec
    from repro.serve.fleet.worker import WorkerHandle

    spec = WorkerSpec(shared_root=str(tmp_path), name="wkill", slots=2,
                      max_len=64, page_tokens=4, quantum=3,
                      hb_interval_s=0.05)
    w = WorkerHandle.launch(spec)
    try:
        w.wait_ready()
        rng = np.random.default_rng(11)
        w.submit("r1", rng.integers(0, 1000, size=8).tolist(), max_new=40)
        # run until tokens stream back AND at least one heartbeat flush
        # has landed in the shared domain, then kill mid-decode
        tier = SharedTier(Path(str(tmp_path)) / "domain",
                          capacity_bytes=spec.shared_capacity)
        seen = 0
        deadline = time.time() + 180.0
        while time.time() < deadline:
            seen += sum(len(m.get("tokens", [])) for m in w.messages()
                        if m.get("op") == "tokens")
            if seen >= 4 and read_flight(tier, "wkill")[0]:
                break
            time.sleep(0.01)
        assert seen >= 4, "worker never started decoding"
        w.kill()
        assert not w.alive()

        fe = FleetFrontend([w])
        post = fe.postmortem(0, last=64)
        assert post["worker"] == "wkill"
        names = {r["name"] for r in post["records"]}
        assert "step" in names               # decode steps made it out
        assert names & {"submit", "prefill", "prefix_match"}
        assert all(r["proc"] == "wkill" for r in post["records"])
        # torn final record: the same read path tolerates a mid-append
        # kill — only the torn line drops, the timeline stays readable
        before = len(fe.postmortem(0)["records"])
        tier.append(flight_key("wkill"), b'{"name":"step","ph":"X","ts":9')
        post2 = fe.postmortem(0)
        assert post2["torn"] == post["torn"] + 1
        assert len(post2["records"]) == before
    finally:
        w.stop()
