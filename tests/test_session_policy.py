"""ResilienceSession transactions + checkpoint policies (repro/api)."""

import math
import threading

import numpy as np
import pytest

from repro.api.policy import (
    DalyPolicy,
    DrainAwarePolicy,
    IntervalPolicy,
    PolicyContext,
)
from repro.api.session import ResilienceSession
from repro.cluster.topology import NodeState, VirtualCluster
from repro.core import parity
from repro.core.nam import NAMDevice
from repro.core.scr import SCRManager, Strategy
from repro.memory.stack import TierStack
from repro.memory.store import NAMStore, OffloadOp
from repro.memory.tiers import (
    CapacityError,
    MemoryHierarchy,
    MemoryTier,
    TierKind,
    TierSpec,
)

STATE = {
    "w": np.arange(4000, dtype=np.float32),
    "step": np.int32(7),
}
TEMPLATE = {
    "w": np.zeros(4000, np.float32),
    "step": np.int32(0),
}


def make_session(tmp_path, strategy=Strategy.BUDDY, policy=None, **kw):
    cl = VirtualCluster(4, 4, root=tmp_path / "run", xor_group_size=4)
    hier = MemoryHierarchy(cl)
    nam = NAMDevice(hier.nam_tier) if strategy == Strategy.NAM_XOR else None
    scr = SCRManager(cl, hier, nam=nam, strategy=strategy, procs_per_node=2, **kw)
    return cl, hier, ResilienceSession(scr, policy=policy)


def step_artifacts(scr, step):
    """Every key mentioning `step` across the stack and all node NVMs."""
    tag = f"step{step:08d}"
    found = [k for k in scr.stack.keys() if tag in k]
    for rank in scr.cluster.up_ranks():
        found += [k for k in scr.hierarchy.nvm(rank).keys() if tag in k]
    if scr.nam is not None:
        found += [k for k in scr.nam.tier.keys() if tag in k]
    return found


# --------------------------------------------------------------------- #
# policy math
# --------------------------------------------------------------------- #


def test_interval_policy_modulo():
    p = IntervalPolicy(3)
    decisions = [p.should_checkpoint(PolicyContext(step=s)) for s in range(1, 7)]
    assert decisions == [False, False, True, False, False, True]
    assert not IntervalPolicy(0).should_checkpoint(PolicyContext(step=5))


def test_daly_interval_matches_first_order_for_small_cost():
    # delta << M: tau ~= sqrt(2*delta*M) - delta
    delta, mtbf = 1.0, 10_000.0
    tau = DalyPolicy(mtbf, checkpoint_cost_s=delta).optimal_interval_s()
    first_order = math.sqrt(2 * delta * mtbf) - delta
    assert abs(tau - first_order) / first_order < 0.05


def test_daly_interval_scaling_and_saturation():
    delta = 1.0
    tau1 = DalyPolicy(10_000.0, checkpoint_cost_s=delta).optimal_interval_s()
    tau4 = DalyPolicy(40_000.0, checkpoint_cost_s=delta).optimal_interval_s()
    # sqrt scaling in MTBF (4x MTBF -> ~2x interval)
    assert 1.85 < tau4 / tau1 < 2.15
    # more expensive checkpoints -> longer interval
    assert (DalyPolicy(10_000.0, checkpoint_cost_s=4.0).optimal_interval_s()
            > tau1)
    # degenerate regime: cost >= 2*MTBF -> checkpoint once per MTBF
    assert DalyPolicy(10.0, checkpoint_cost_s=100.0).optimal_interval_s() == 10.0


def test_daly_learns_measured_cost():
    p = DalyPolicy(10_000.0, ema=1.0)   # no seed: bootstrap
    assert p.should_checkpoint(PolicyContext(step=1, now_s=0.0))
    p.observe_save(None, 4.0)
    assert p.checkpoint_cost_s == 4.0
    tau = p.optimal_interval_s()
    assert abs(tau - DalyPolicy(10_000.0, checkpoint_cost_s=4.0)
               .optimal_interval_s()) < 1e-9
    # clock-driven decision: not yet due, then due
    ctx = PolicyContext(step=2, now_s=100.0, last_checkpoint_wall_s=100.0 - tau / 2)
    assert not p.should_checkpoint(ctx)
    ctx = PolicyContext(step=3, now_s=100.0, last_checkpoint_wall_s=100.0 - 2 * tau)
    assert p.should_checkpoint(ctx)


def test_drain_aware_policy_defers_under_backlog():
    inner = IntervalPolicy(1)
    p = DrainAwarePolicy(inner, max_backlog=2)
    busy = PolicyContext(step=5, drain_backlog=2, drain_depth=2)
    idle = PolicyContext(step=5, drain_backlog=0, drain_depth=2)
    assert not p.should_checkpoint(busy)
    assert p.deferred == 1
    assert p.should_checkpoint(idle)
    # default threshold is the executor depth (backpressure point)
    q = DrainAwarePolicy(inner)
    assert not q.should_checkpoint(PolicyContext(step=5, drain_backlog=2, drain_depth=2))
    assert q.should_checkpoint(PolicyContext(step=5, drain_backlog=1, drain_depth=2))


# --------------------------------------------------------------------- #
# session transactions
# --------------------------------------------------------------------- #


def test_session_commit_roundtrip(tmp_path):
    cl, hier, session = make_session(tmp_path, policy=IntervalPolicy(2))
    with session:
        assert not session.need_checkpoint(1)
        assert session.need_checkpoint(2)
        session.start_checkpoint(2)
        for k, v in STATE.items():
            session.route(k, v)
        rec = session.complete_checkpoint(meta={"tag": "x"})
        assert rec.step == 2 and session.last_checkpoint_step == 2
        restored, step = session.restore_latest(dict(TEMPLATE))
        assert step == 2
        assert np.asarray(restored["w"]).tobytes() == STATE["w"].tobytes()
        assert session.checkpoint_meta(2) == {"tag": "x"}
    assert session.closed


def test_session_abort_leaves_no_fragments(tmp_path):
    cl, hier, session = make_session(tmp_path, strategy=Strategy.NAM_XOR)
    with session:
        session.save(1, STATE)
        session.start_checkpoint(2)
        session.route("w", STATE["w"] + 1)
        assert session.complete_checkpoint(valid=False) is None
        assert session.stats["aborted"] == 1
        # the aborted transaction is invisible in every tier
        assert step_artifacts(session.scr, 2) == []
        restored, step = session.restore_latest(dict(TEMPLATE))
        assert step == 1
        assert np.asarray(restored["w"]).tobytes() == STATE["w"].tobytes()


def test_session_failed_commit_sweeps_partials(tmp_path, monkeypatch):
    cl, hier, session = make_session(tmp_path, flush_every=1)
    with session:
        # the sync drain fails mid-commit, after the NVM foreground writes
        monkeypatch.setattr(
            session.scr, "_drain_to_global",
            lambda *a, **kw: (_ for _ in ()).throw(IOError("pfs died")))
        with pytest.raises(IOError):
            session.save(3, STATE)
        assert session.stats["aborted"] == 1
        # no partial fragments in any tier, and nothing restorable
        assert step_artifacts(session.scr, 3) == []
        with pytest.raises(IOError):
            session.restore_latest(dict(TEMPLATE))


def test_session_checkpoint_scope_aborts_on_exception(tmp_path):
    cl, hier, session = make_session(tmp_path)
    with session:
        with pytest.raises(ValueError):
            with session.checkpoint(5):
                session.route("w", STATE["w"])
                raise ValueError("app blew up mid-transaction")
        assert session.stats["aborted"] == 1
        assert step_artifacts(session.scr, 5) == []
        # the session is reusable after the abort
        session.save(6, STATE)
        assert session.available_steps() == [6]


def test_checkpoint_scope_tolerates_manual_resolution(tmp_path):
    cl, hier, session = make_session(tmp_path)
    with session:
        with session.checkpoint(4):
            session.route("w", STATE["w"])
            session.abort_checkpoint()      # body resolves the txn itself
        assert session.stats["aborted"] == 1
        assert session.available_steps() == []
        with session.checkpoint(5):
            session.route("w", STATE["w"])
            session.complete_checkpoint()   # explicit commit inside the scope
        assert session.stats["committed"] == 1
        assert session.available_steps() == [5]


def test_session_transaction_protocol_errors(tmp_path):
    cl, hier, session = make_session(tmp_path)
    with session:
        with pytest.raises(RuntimeError):
            session.route("w", STATE["w"])          # no open transaction
        with pytest.raises(RuntimeError):
            session.complete_checkpoint()           # no open transaction
        session.start_checkpoint(1)
        with pytest.raises(RuntimeError):
            session.start_checkpoint(2)             # nested transaction
        session.route("w", STATE["w"])
        with pytest.raises(ValueError):
            session.route("w", STATE["w"])          # duplicate key
        session.complete_checkpoint()
    with pytest.raises(RuntimeError):
        session.start_checkpoint(9)                 # closed session


def test_session_close_is_idempotent_and_stops_threads(tmp_path):
    cl, hier, session = make_session(tmp_path, async_drain=True)
    session.save(1, STATE)
    session.wait_drained()
    session.close()
    session.close()     # idempotent
    scr = session.scr
    assert scr._executor._thread is None or not scr._executor._thread.is_alive()
    assert scr.beeond._drainer is None
    # the engine close is idempotent too (and usable as a context manager)
    scr.close()
    with pytest.raises(RuntimeError):
        session.save(2, STATE)


# --------------------------------------------------------------------- #
# trainer-level policy wiring
# --------------------------------------------------------------------- #


def test_trainer_drives_checkpoints_through_policy(tmp_path):
    from repro.configs import get_config
    from repro.data.pipeline import TokenPipeline
    from repro.models.registry import get_model
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer

    cfg = get_config("phi3-mini-3.8b").reduced()
    model = get_model(cfg)
    cluster = VirtualCluster(4, 0, root=tmp_path / "run")
    pipeline = TokenPipeline(cfg.vocab_size, global_batch=4, seq_len=32)
    trainer = Trainer.for_cluster(
        cfg, model, pipeline, cluster,
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=2),
        ckpt_every=3, policy=IntervalPolicy(3))
    report = trainer.run(7)
    # steps 3 and 6 by policy, 7 as the final resumability checkpoint
    assert report.checkpoints == 3
    assert trainer.session.stats["committed"] == 3
    assert trainer.scr.available_steps()[-1] == 7
    trainer.close()
    trainer.close()   # idempotent


def test_trainer_installs_cadence_on_bare_session(tmp_path):
    """A session without an explicit policy must not checkpoint every
    step: the trainer installs IntervalPolicy(ckpt_every) on it, while a
    session carrying its own policy keeps it (and a conflicting trainer
    policy= is rejected)."""
    from repro.configs import get_config
    from repro.data.pipeline import TokenPipeline
    from repro.models.registry import get_model
    from repro.train.trainer import Trainer

    cfg = get_config("phi3-mini-3.8b").reduced()
    model = get_model(cfg)
    cl, hier, bare = make_session(tmp_path)
    pipeline = TokenPipeline(cfg.vocab_size, global_batch=4, seq_len=32)
    trainer = Trainer(cfg, model, pipeline, bare, ckpt_every=50)
    assert isinstance(trainer.session.policy, IntervalPolicy)
    assert trainer.session.policy.every == 50
    cl2, hier2, owned = make_session(tmp_path / "b", policy=IntervalPolicy(7))
    trainer2 = Trainer(cfg, model, pipeline, owned, ckpt_every=50)
    assert trainer2.session.policy.every == 7
    with pytest.raises(ValueError):
        Trainer(cfg, model, pipeline, owned, policy=IntervalPolicy(3))
    bare.close()
    owned.close()


# --------------------------------------------------------------------- #
# TierStack.offload (NAM parity path)
# --------------------------------------------------------------------- #


def _two_level_stack(nam=None, cap=1 << 20, admission_fraction=None):
    fast = MemoryTier(TierSpec(TierKind.DRAM, cap, 80e9, 80e9, 1e-7))
    slow = MemoryTier(TierSpec(TierKind.GLOBAL, 1 << 30, 5e9, 5e9, 5e-4))
    levels = [("cache", fast)]
    if nam is not None:
        levels.append(("nam", NAMStore(nam)))
    levels.append(("global", slow))
    return TierStack(levels, admission_fraction=admission_fraction), fast, slow


def test_offload_routes_parity_to_nam_byte_identical(tmp_path):
    rng = np.random.default_rng(0)
    frags = [rng.integers(0, 256, 4096, dtype=np.uint8).tobytes() for _ in range(4)]
    spec = TierSpec(TierKind.NAM, 1 << 20, 11.5e9, 11.5e9, 1.8e-6, shared=True)
    nam = NAMDevice(MemoryTier(spec))
    stack, fast, slow = _two_level_stack(nam=nam)
    op = OffloadOp("xor_parity", sources=[lambda f=f: f for f in frags],
                   nbytes=len(frags[0]))
    t = stack.offload("nam_parity/step00000001/group000", op)
    assert t > 0 and stack.stats["offloads"] == 1
    got = stack.get("nam_parity/step00000001/group000")
    # byte-identical with the old direct NAMDevice path
    direct_nam = NAMDevice(MemoryTier(spec))
    direct_nam.alloc("p", len(frags[0]))
    direct_nam.offload_parity("p", [lambda f=f: f for f in frags], len(frags[0]))
    assert got == direct_nam.get("p") == parity.encode_nam_parity(frags)
    # it landed on the NAM level, not the cache or global level
    assert nam.exists("nam_parity/step00000001/group000")
    assert not fast.exists("nam_parity/step00000001/group000")
    assert not slow.exists("nam_parity/step00000001/group000")


def test_offload_host_fallback_without_capable_level():
    rng = np.random.default_rng(1)
    frags = [rng.integers(0, 256, 1024, dtype=np.uint8).tobytes() for _ in range(3)]
    stack, fast, slow = _two_level_stack(nam=None)
    op = OffloadOp("xor_parity", sources=[lambda f=f: f for f in frags],
                   nbytes=len(frags[0]))
    stack.offload("nam_parity/x", op)
    assert stack.stats["offloads"] == 0    # host fallback, not an offload
    assert stack.get("nam_parity/x") == parity.encode_nam_parity(frags)


def test_offload_protects_current_step_parity():
    """Pool pressure may evict an older step's parity but must never
    sacrifice a region of the step being checkpointed — that would
    silently degrade a save that reports success."""
    spec = TierSpec(TierKind.NAM, 4096, 11.5e9, 11.5e9, 1.8e-6, shared=True)
    nam = NAMDevice(MemoryTier(spec))     # pool fits exactly one region
    stack, fast, slow = _two_level_stack(nam=nam)
    frags = [bytes([i]) * 4096 for i in range(2)]
    op = OffloadOp("xor_parity", sources=[lambda f=f: f for f in frags],
                   nbytes=4096)
    stack.offload("nam_parity/step00000001/group000", op,
                  protect_prefix="nam_parity/step00000001")
    with pytest.raises(CapacityError):
        stack.offload("nam_parity/step00000001/group001", op,
                      protect_prefix="nam_parity/step00000001")
    assert nam.exists("nam_parity/step00000001/group000")   # survived
    # a NEWER step's offload may evict the old step's parity
    stack.offload("nam_parity/step00000002/group000", op,
                  protect_prefix="nam_parity/step00000002")
    assert nam.exists("nam_parity/step00000002/group000")
    assert not nam.exists("nam_parity/step00000001/group000")


def test_discard_sweeps_host_fallback_parity(tmp_path):
    """Parity that fell back to the host path (stack without a nam level)
    lands on lower stack levels — prune/discard must sweep it too."""
    cl = VirtualCluster(4, 4, root=tmp_path / "run", xor_group_size=4)
    hier = MemoryHierarchy(cl)
    nam = NAMDevice(hier.nam_tier)
    stack = TierStack.for_hierarchy(hier)   # deliberately no nam level
    scr = SCRManager(cl, stack, nam=nam, strategy=Strategy.NAM_XOR,
                     procs_per_node=2, flush_every=0, keep=1)
    with ResilienceSession(scr) as session:
        session.save(1, STATE)
        assert any(k.startswith("nam_parity/step00000001")
                   for k in scr.stack.keys())
        session.save(2, STATE)   # keep=1: step 1 pruned, parity swept too
        assert not any(k.startswith("nam_parity/step00000001")
                       for k in scr.stack.keys())
        scr.discard(2)
        assert not any(k.startswith("nam_parity/") for k in scr.stack.keys())
        assert step_artifacts(scr, 2) == []


def test_nam_xor_save_restore_via_stack_offload(tmp_path):
    """End-to-end: NAM_XOR redundancy reaches the NAM via TierStack.offload
    and reconstruction after a node loss still round-trips."""
    cl, hier, session = make_session(tmp_path, strategy=Strategy.NAM_XOR,
                                     flush_every=0)
    with session:
        session.save(3, STATE)
        scr = session.scr
        assert scr.stack.stats["offloads"] == len(cl.xor_groups)
        # parity bytes on the NAM match the host oracle for each group
        for gid in range(len(cl.xor_groups)):
            region = f"nam_parity/step{3:08d}/group{gid:03d}"
            assert scr.nam.exists(region)
        cl.fail(2, NodeState.FAILED_NODE)
        cl.recover(2)
        session.invalidate_node(2)
        restored, step = session.restore_latest(dict(TEMPLATE))
        assert step == 3
        assert np.asarray(restored["w"]).tobytes() == STATE["w"].tobytes()


# --------------------------------------------------------------------- #
# TierStack admission control
# --------------------------------------------------------------------- #


def test_admission_control_routes_oversized_values():
    stack, fast, slow = _two_level_stack(cap=1 << 20, admission_fraction=0.25)
    small = b"s" * 1024
    big = b"b" * (1 << 19)     # 50% of the fast level: refused there
    stack.put("ckpt/step00000001/small.bin", small)
    stack.put("ckpt/step00000001/big.bin", big)
    assert fast.exists("ckpt/step00000001/small.bin")
    assert not fast.exists("ckpt/step00000001/big.bin")
    assert slow.exists("ckpt/step00000001/big.bin")
    assert stack.stats["admission_routed"] == 1
    # both readable through the stack; the oversized value is NOT
    # promoted back into the cache level on read
    assert stack.get("ckpt/step00000001/big.bin") == big
    assert not fast.exists("ckpt/step00000001/big.bin")


def test_admission_control_stream_size_hint():
    stack, fast, slow = _two_level_stack(cap=1 << 20, admission_fraction=0.25)
    chunks = [b"x" * 1024] * 512    # 512 KiB total
    stack.put_stream("ckpt/step00000002/frag.bin", iter(chunks),
                     size_hint=512 * 1024)
    assert not fast.exists("ckpt/step00000002/frag.bin")
    assert slow.exists("ckpt/step00000002/frag.bin")
    assert stack.stats["admission_routed"] == 1


def test_admission_fraction_validation():
    with pytest.raises(ValueError):
        _two_level_stack(admission_fraction=0.0)
    with pytest.raises(ValueError):
        _two_level_stack(admission_fraction=1.5)
