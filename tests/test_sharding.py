"""Sharding rules: logical-axis mapping, divisibility pruning."""

import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

import jax

from repro.configs import get_config
from repro.distributed.sharding import (
    DECODE_RULES,
    TRAIN_RULES,
    ShardingRules,
    fit_spec,
    specs_from_axes,
)
from repro.models.registry import get_model


def fake_mesh(shape=(2, 2), axes=("data", "model")):
    devs = np.array([jax.devices("cpu")[0]] * int(np.prod(shape))).reshape(shape)
    return Mesh(devs, axes)


def test_rules_map_known_axes():
    mesh = fake_mesh()
    spec = specs_from_axes({"x": ("vocab", "d_model")}, TRAIN_RULES, mesh)
    assert spec == {"x": P("model", None)}


def test_unknown_axis_fails_loudly():
    with pytest.raises(KeyError):
        TRAIN_RULES.spec_for(("not_an_axis",))


def test_pod_axis_stripped_on_single_pod():
    mesh = fake_mesh()
    spec = specs_from_axes({"x": ("batch", None)}, TRAIN_RULES, mesh)
    assert spec == {"x": P(("data",), None)}


def test_pod_axis_kept_on_multi_pod():
    mesh = fake_mesh((2, 2, 2), ("pod", "data", "model"))
    spec = specs_from_axes({"x": ("batch", None)}, TRAIN_RULES, mesh)
    assert spec == {"x": P(("pod", "data"), None)}


def test_decode_rules_shard_kv_seq():
    mesh = fake_mesh()
    spec = specs_from_axes({"x": ("layers", "batch", "kv_seq", None, None)},
                           DECODE_RULES, mesh)
    assert spec == {"x": P(None, ("data",), "model", None, None)}


def test_fit_spec_prunes_non_divisible():
    mesh = fake_mesh((4, 2), ("data", "model"))
    s = fit_spec(P(("data",), "model"), (1, 64), mesh)   # batch=1: replicate
    assert s == P(None, "model")
    s2 = fit_spec(P(("data",), "model"), (8, 63), mesh)  # 63 % 2 != 0
    assert s2 == P(("data",), None)


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "deepseek-moe-16b",
                                  "rwkv6-3b", "zamba2-2.7b", "whisper-tiny"])
def test_param_axes_all_resolvable(arch):
    """Every logical axis every model emits must have a rule."""
    cfg = get_config(arch).with_tp(16)
    model = get_model(cfg)
    mesh = fake_mesh((16, 16))
    specs = specs_from_axes(model.param_axes(cfg), TRAIN_RULES, mesh)
    assert specs is not None
    cache_specs = specs_from_axes(model.cache_axes(cfg), DECODE_RULES, mesh)
    assert cache_specs is not None


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "starcoder2-7b",
                                  "minicpm3-4b", "qwen2-moe-a2.7b"])
def test_padded_dims_divisible_at_tp16(arch):
    """At TP=16 every sharded param dim must divide evenly."""
    cfg = get_config(arch).with_tp(16)
    model = get_model(cfg)
    mesh = fake_mesh((16, 16))
    shapes = model.param_shapes(cfg)
    specs = specs_from_axes(model.param_axes(cfg), TRAIN_RULES, mesh)
    flat_shapes = jax.tree_util.tree_leaves(shapes)
    flat_specs = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    for shp, spec in zip(flat_shapes, flat_specs):
        for dim, entry in zip(shp.shape, tuple(spec)):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([mesh.shape[n] for n in names]))
            assert dim % size == 0, (arch, shp.shape, spec)
