"""Serving fleet: prefix publish/subscribe, tail sharing, admission.

Layered like the subsystem: synthetic-layout tests for the trie
mechanics (no jax model, milliseconds), stub-worker tests for the
front-end's admission logic, and slow end-to-end tests spawning real
worker processes over one shared domain.
"""

import time

import numpy as np
import pytest

from repro.memory.codecs import CodecRule, make_codec
from repro.memory.shared import SharedTier
from repro.memory.stack import HitRatePromotion, KeyClass, TierStack
from repro.memory.tiers import MemoryTier, TierKind, TierSpec
from repro.serve.fleet.board import PrefixBoard
from repro.serve.prefix import LaneLayout, PrefixCache, prefix_page_key

MAX_LEN, PT = 16, 4


TEMPLATE = {"k": np.zeros((2, 1, MAX_LEN, 2, 4), np.float32),
            "v": np.zeros((2, 1, MAX_LEN, 2, 4), np.float32)}


def make_layout():
    axes = {"k": ("layers", "batch", "kv_seq", "heads", "head_dim"),
            "v": ("layers", "batch", "kv_seq", "heads", "head_dim")}
    return LaneLayout(TEMPLATE, axes)


def make_stack(shared=None, fast_bytes=1 << 20, codec=None):
    levels = [("hbm", MemoryTier(TierSpec(TierKind.HBM, fast_bytes,
                                          450e9, 450e9, 1e-7)))]
    if shared is not None:
        levels.append(("shared", shared))
    else:
        levels.append(("global", MemoryTier(TierSpec(
            TierKind.GLOBAL, 1 << 30, 5e9, 5e9, 5e-4))))
    return TierStack(levels, promotion=HitRatePromotion(k=2, window=64),
                     codecs={KeyClass.KV: CodecRule(codec)} if codec else None)


def rand_lane(layout, rng):
    return {k: rng.normal(size=v.shape).astype(np.float32)
            for k, v in TEMPLATE.items()}


# --------------------------------------------------------------------------- #
# publish / subscribe: export_records + adopt_nodes
# --------------------------------------------------------------------------- #

def publish(cache, stack_to, published):
    fresh = []
    for rec in cache.export_records():
        if rec["digest"] in published:
            continue
        payload = cache.stack.get(prefix_page_key(rec["digest"]),
                                  promote=False)
        stack_to.put_at("shared", prefix_page_key(rec["digest"]), payload)
        published.add(rec["digest"])
        fresh.append(rec)
    return fresh


def test_adopt_nodes_cross_cache(tmp_path):
    """B adopts A's records and reads the payloads through the shared
    level — the in-process model of two fleet workers."""
    layout, rng = make_layout(), np.random.default_rng(0)
    dom = SharedTier(tmp_path / "dom")
    a = PrefixCache(make_stack(shared=dom), layout, page_tokens=PT)
    b = PrefixCache(make_stack(shared=SharedTier(tmp_path / "dom")),
                    layout, page_tokens=PT)
    tokens = list(range(12))
    lane = rand_lane(layout, rng)
    a.extend(tokens, 8, lane)                      # two full pages
    recs = publish(a, a.stack, set())
    assert len(recs) == 2
    assert b.adopt_nodes(recs) == 2
    assert b.stats["nodes_adopted"] == 2
    covered, path = b.match(tokens)
    assert covered == 8 and len(path) == 2
    # payload readable through B's stack (shared level hit), content-equal
    part = b.read_node_part(path[0])
    np.testing.assert_array_equal(part["k"], layout.extract(lane, 0, PT)["k"])
    assert b.stack.stats()["hits_shared"] >= 1


def test_adopt_skips_duplicates_and_orphans(tmp_path):
    layout, rng = make_layout(), np.random.default_rng(1)
    a = PrefixCache(make_stack(), layout, page_tokens=PT)
    b = PrefixCache(make_stack(), layout, page_tokens=PT)
    a.extend(list(range(8)), 8, rand_lane(layout, rng))
    recs = a.export_records()
    assert b.adopt_nodes(recs) == 2
    assert b.adopt_nodes(recs) == 0               # idempotent
    orphan = dict(recs[1], digest="feedfacefeedfacefeedface",
                  parent="0" * 24, chunk=[99, 98, 97, 96])
    assert b.adopt_nodes([orphan]) == 0           # unknown parent skipped
    assert len(b) == 2


def test_adopted_nodes_count_toward_budget_and_evict(tmp_path):
    layout, rng = make_layout(), np.random.default_rng(2)
    a = PrefixCache(make_stack(), layout, page_tokens=PT)
    a.extend(list(range(8)), 8, rand_lane(layout, rng))
    recs = a.export_records()
    nbytes = sum(r["nbytes"] for r in recs)
    b = PrefixCache(make_stack(), layout, page_tokens=PT,
                    capacity_bytes=nbytes)        # exactly fits
    assert b.adopt_nodes(recs) == 2
    assert b.cached_bytes() == nbytes
    # pressure: a locally inserted chain evicts the adopted tail
    b.extend(list(range(100, 108)), 8, rand_lane(layout, rng))
    assert b.cached_bytes() <= nbytes
    assert b.stats["pages_evicted"] >= 1


def test_export_records_orders_parents_first():
    layout, rng = make_layout(), np.random.default_rng(3)
    a = PrefixCache(make_stack(), layout, page_tokens=PT)
    a.extend(list(range(12)), 12, rand_lane(layout, rng))
    recs = a.export_records()
    seen = set()
    for rec in recs:
        assert rec["parent"] == "" or rec["parent"] in seen
        seen.add(rec["digest"])


# --------------------------------------------------------------------------- #
# PrefixBoard
# --------------------------------------------------------------------------- #

def test_board_publish_poll_roundtrip(tmp_path):
    a, b = PrefixBoard(tmp_path), PrefixBoard(tmp_path)
    recs = [{"digest": "d1", "parent": "", "chunk": [1, 2], "end": 2,
             "nbytes": 10, "crc32": 7}]
    assert a.publish(recs) == 1
    assert b.poll() == recs
    assert b.poll() == []                         # cursor advanced
    a.publish([dict(recs[0], digest="d2", parent="d1")])
    got = b.poll()
    assert [r["digest"] for r in got] == ["d2"]
    # a's own cursor sees everything it published too
    assert [r["digest"] for r in a.poll()] == ["d1", "d2"]


def test_board_ignores_torn_tail(tmp_path):
    a, b = PrefixBoard(tmp_path), PrefixBoard(tmp_path)
    a.publish([{"digest": "d1", "parent": "", "chunk": [], "end": 0,
                "nbytes": 0, "crc32": 0}])
    with open(a.path, "ab") as f:
        f.write(b'{"digest": "partial')          # torn concurrent append
    got = b.poll()
    assert [r["digest"] for r in got] == ["d1"]  # whole lines only
    assert b.poll() == []


def test_board_empty_poll(tmp_path):
    assert PrefixBoard(tmp_path).poll() == []


# --------------------------------------------------------------------------- #
# satellite 1: partial-page tail sharing (synthetic layout)
# --------------------------------------------------------------------------- #

def test_register_and_match_tail():
    layout, rng = make_layout(), np.random.default_rng(4)
    cache = PrefixCache(make_stack(), layout, page_tokens=PT)
    tokens = list(range(10))                      # 2 pages + 2-token tail
    lane = rand_lane(layout, rng)
    cache.extend(tokens, 8, lane)
    node = cache.register_tail(tokens, 10, lane)
    assert node is not None and node.end == 10 and len(node.chunk) == 2
    assert cache.stats["tail_pages_inserted"] == 1
    # same-prefix request with a longer suffix reuses the tail
    req = tokens + [77, 78, 79]
    covered, path = cache.match(req)
    assert covered == 8
    tail = cache.match_tail(req, covered, path)
    assert tail is node
    part = cache.read_node_part(tail)
    np.testing.assert_array_equal(
        part["k"], layout.extract(lane, 8, 10)["k"])
    assert cache.stats["tail_hits"] == 1
    assert cache.stats["tail_tokens_reused"] == 2


def test_tail_requires_full_page_ancestors():
    layout, rng = make_layout(), np.random.default_rng(5)
    cache = PrefixCache(make_stack(), layout, page_tokens=PT)
    lane = rand_lane(layout, rng)
    # no full pages cached for this chain -> tail refuses to anchor
    assert cache.register_tail(list(range(10)), 10, lane) is None
    cache.extend(list(range(8)), 8, lane)
    assert cache.register_tail(list(range(8)), 8, lane) is None  # no tail


def test_match_tail_prefers_longest():
    layout, rng = make_layout(), np.random.default_rng(6)
    cache = PrefixCache(make_stack(), layout, page_tokens=PT)
    tokens = list(range(8))
    lane = rand_lane(layout, rng)
    cache.extend(tokens, 8, lane)
    cache.register_tail(tokens + [50], 9, lane)
    cache.register_tail(tokens + [50, 51], 10, lane)
    tail = cache.match_tail(tokens + [50, 51, 52], 8,
                            cache.match(tokens)[1])
    assert tail.end == 10


def test_tail_mismatch_not_matched():
    layout, rng = make_layout(), np.random.default_rng(7)
    cache = PrefixCache(make_stack(), layout, page_tokens=PT)
    tokens = list(range(8))
    lane = rand_lane(layout, rng)
    cache.extend(tokens, 8, lane)
    cache.register_tail(tokens + [50, 51], 10, lane)
    assert cache.match_tail(tokens + [60, 61], 8,
                            cache.match(tokens)[1]) is None
    # suffix shorter than the tail cannot use it either
    assert cache.match_tail(tokens + [50], 8, cache.match(tokens)[1]) is None


# --------------------------------------------------------------------------- #
# satellite 2: quantized prefix pages survive demotion
# --------------------------------------------------------------------------- #

def test_quantized_prefix_page_readable_after_demotion():
    """Int8 kv codec: a prefix payload demoted past the fast level
    decodes to different bytes; the fetch path must re-anchor integrity
    to the decoded stream instead of failing the insert-time crc."""
    layout, rng = make_layout(), np.random.default_rng(8)
    part_bytes = 2 * 2 * 1 * PT * 2 * 4 * 4
    stack = make_stack(fast_bytes=int(part_bytes * 1.5),
                       codec=make_codec("int8", dtype="float32", block=4))
    cache = PrefixCache(stack, layout, page_tokens=PT)
    lane = rand_lane(layout, rng)
    path = cache.extend(list(range(12)), 8, lane)
    cache.extend(list(range(100, 112)), 8, rand_lane(layout, rng))
    st = stack.stats()
    # pressure really demoted payloads: evictions moved them down
    # through the int8 codec (encoded bytes < plaintext)
    assert st["evictions"] >= 1
    assert 0 < st["kv_bytes_encoded_out"] < st["kv_bytes_encoded"]
    part = cache.read_node_part(path[0])          # would IOError before fix
    np.testing.assert_allclose(
        part["k"], layout.extract(lane, 0, PT)["k"], rtol=0.1, atol=0.05)
    covered, p2 = cache.match(list(range(12)))
    fresh = layout.zero_lane()
    assert cache.fetch_into(p2, fresh) == 8       # nodes survive the fetch
    assert len(cache) >= 2


def test_lossless_codec_keeps_strict_crc():
    layout, rng = make_layout(), np.random.default_rng(9)
    stack = make_stack(codec=make_codec("zlib"))
    cache = PrefixCache(stack, layout, page_tokens=PT)
    lane = rand_lane(layout, rng)
    path = cache.extend(list(range(8)), 8, lane)
    part = cache.read_node_part(path[0])
    np.testing.assert_array_equal(part["k"], layout.extract(lane, 0, PT)["k"])


# --------------------------------------------------------------------------- #
# front-end admission logic (stub workers)
# --------------------------------------------------------------------------- #

class StubWorker:
    """Pipe-free WorkerHandle stand-in: finishes a request after
    ``delay_pumps`` message polls."""

    def __init__(self, delay_pumps=1):
        self.submitted = []
        self._pending = []
        self.delay = delay_pumps

    def submit(self, rid, prompt, max_new, weight=1):
        self.submitted.append({"rid": rid, "prompt": list(prompt),
                               "max_new": max_new, "weight": weight})
        self._pending.append([self.delay, rid, max_new])

    def messages(self):
        out = []
        for ent in list(self._pending):
            ent[0] -= 1
            if ent[0] <= 0:
                out.append({"op": "done", "rid": ent[1],
                            "tokens": [0] * ent[2]})
                self._pending.remove(ent)
        return out

    def stats(self):
        return {}

    def stop(self):
        pass


def make_frontend(n_workers=2, **kw):
    from repro.serve.fleet.frontend import FleetFrontend
    workers = [StubWorker() for _ in range(n_workers)]
    return FleetFrontend(workers, **kw), workers


def test_quota_throttles_only_the_noisy_tenant():
    from repro.serve.fleet.frontend import TenantQuota
    fe, workers = make_frontend(
        1, quotas={"noisy": TenantQuota(1)},
        default_quota=TenantQuota(8))
    noisy = [fe.submit([1, 2], 3, tenant="noisy") for _ in range(4)]
    quiet = [fe.submit([1, 2], 3, tenant="quiet") for _ in range(2)]
    fe.pump()
    w = workers[0]
    # one noisy dispatch (quota 1), both quiet dispatches, throttling seen
    assert sum(1 for s in w.submitted if s["rid"] in noisy) == 1
    assert sum(1 for s in w.submitted if s["rid"] in quiet) == 2
    assert fe.stats["throttle_events"] >= 1
    fe.wait(noisy + quiet, timeout=10)
    assert fe.stats["completed"] == 6             # backlog drains eventually
    assert all(len(fe.result(r)) == 3 for r in noisy)


def test_priority_class_maps_to_quantum_weight():
    from repro.serve.fleet.frontend import PriorityClass
    fe, workers = make_frontend(
        1, classes={"lo": PriorityClass("lo", 1),
                    "hi": PriorityClass("hi", 3)})
    fe.submit([1], 2, prio="hi")
    fe.submit([1], 2, prio="lo")
    fe.pump()
    assert [s["weight"] for s in workers[0].submitted] == [3, 1]
    with pytest.raises(ValueError):
        fe.submit([1], 2, prio="nope")


def test_least_loaded_routing():
    from repro.serve.fleet.frontend import FleetFrontend
    workers = [StubWorker(delay_pumps=10), StubWorker(delay_pumps=10)]
    fe = FleetFrontend(workers)
    r1 = fe.submit([1] * 10, 10)                  # cost 20
    fe.pump()
    r2 = fe.submit([1], 1)                        # cost 2 -> other worker
    r3 = fe.submit([1], 1)
    fe.pump()                                     # r1 still in flight
    first = 0 if workers[0].submitted and \
        workers[0].submitted[0]["rid"] == r1 else 1
    assert [s["rid"] for s in workers[first].submitted] == [r1]
    assert {s["rid"] for s in workers[1 - first].submitted} == {r2, r3}


def test_admission_latency_recorded():
    fe, _ = make_frontend(1)
    rid = fe.submit([1], 1, tenant="t")
    fe.wait([rid], timeout=10)
    assert fe.admission_latency_p99("t") >= 0.0
    assert fe.admission_latency_p99("never-dispatched") == 0.0


# --------------------------------------------------------------------------- #
# satellite: checkpoint sessions over the shared root
# --------------------------------------------------------------------------- #

def test_session_restore_across_instances(tmp_path):
    """A checkpoint committed through one session is restorable by a
    session constructed later over the same shared root — the storage
    hierarchy lives on the shared filesystem, not in the process."""
    from repro.api import ResilienceSession

    state = {"w": np.arange(32, dtype=np.float32),
             "b": np.ones(4, np.float32)}
    with ResilienceSession.for_shared_tier(tmp_path / "fleet") as s1:
        s1.save(3, state)
        s1.wait_drained()
    with ResilienceSession.for_shared_tier(tmp_path / "fleet") as s2:
        like = {"w": np.zeros(32, np.float32), "b": np.zeros(4, np.float32)}
        got, step = s2.restore_latest(like)
    assert step == 3
    np.testing.assert_array_equal(got["w"], state["w"])
    np.testing.assert_array_equal(got["b"], state["b"])


# --------------------------------------------------------------------------- #
# slow: real workers over one shared domain
# --------------------------------------------------------------------------- #

def _run_one(w, rid, prompt, max_new=4, timeout=180.0):
    w.submit(rid, prompt, max_new=max_new)
    deadline = time.time() + timeout
    while time.time() < deadline:
        for m in w.messages():
            if m.get("op") == "done" and m["rid"] == rid:
                return m["tokens"]
        time.sleep(0.01)
    raise TimeoutError(f"request {rid} never finished")


@pytest.mark.slow
def test_cross_worker_prefix_reuse(tmp_path):
    """Worker B admits a prompt whose prefix only worker A computed:
    B adopts the published trie nodes, reads the pages out of the shared
    tier, and skips the prefill — the tentpole acceptance criterion."""
    from repro.serve.fleet import WorkerHandle, WorkerSpec

    mk = lambda: WorkerSpec(shared_root=str(tmp_path), slots=2, max_len=32,
                            page_tokens=4, quantum=3)
    a, b = WorkerHandle.launch(mk()), WorkerHandle.launch(mk())
    try:
        a.wait_ready()
        b.wait_ready()
        rng = np.random.default_rng(3)
        sysp = rng.integers(0, 1000, size=13).tolist()
        # "done" implies published: A's trie nodes are on the board
        # before its completion reaches us
        _run_one(a, "a1", sysp + rng.integers(0, 1000, size=4).tolist())
        _run_one(b, "b1", sysp + rng.integers(0, 1000, size=5).tolist())
        sb = b.stats()
        assert sb["scheduler"]["prefill_tokens_saved"] > 0
        assert sb["tier"]["hits_shared"] > 0
        assert sb["prefix"]["nodes_adopted"] > 0
        # drain protocol: nothing unfinished, but the op answers
        assert b.drain() == []
    finally:
        a.stop()
        b.stop()


@pytest.mark.slow
def test_fleet_frontend_end_to_end(tmp_path):
    from repro.serve.fleet import FleetFrontend, TenantQuota, WorkerSpec

    specs = [WorkerSpec(shared_root=str(tmp_path), slots=2, max_len=32,
                        page_tokens=4, quantum=3) for _ in range(2)]
    rng = np.random.default_rng(7)
    sysp = rng.integers(0, 1000, size=9).tolist()
    with FleetFrontend.launch(specs,
                              quotas={"noisy": TenantQuota(1)}) as fe:
        rids = [fe.submit(
            sysp + rng.integers(0, 1000, size=int(rng.integers(3, 6))).tolist(),
            max_new=4, tenant="noisy" if i % 2 else "quiet")
            for i in range(4)]
        fe.wait(rids, timeout=300)
        outs = [fe.result(r) for r in rids]
        assert all(len(o) == 4 for o in outs)
        assert fe.stats["completed"] == 4
        assert fe.stats["throttle_events"] >= 1   # noisy went over quota
