"""SharedTier: the fleet's cross-process cache domain.

Unit tests cover the BufferStore contract plus the two semantics the
fleet leans on — rename-commit (readers never see torn objects) and
publisher-pid refcounted delete (worker A evicting its copy cannot
unlink an object worker B also published).  The race tests spawn real
processes hammering one domain; children avoid jax entirely, so they
start in milliseconds.
"""

import multiprocessing as mp
import zlib

import pytest

from repro.memory.shared import SharedTier
from repro.memory.tiers import CapacityError


def _blob(key: str, size: int = 512) -> bytes:
    # deterministic key -> content, verifiable from any process
    seed = zlib.crc32(key.encode()).to_bytes(4, "big")
    return (seed * (size // 4 + 1))[:size]


# --------------------------------------------------------------------------- #
# unit: BufferStore contract
# --------------------------------------------------------------------------- #

def test_put_get_roundtrip(tmp_path):
    st = SharedTier(tmp_path / "dom")
    st.put("kv/page/a.bin", b"hello")
    assert st.get("kv/page/a.bin") == b"hello"
    assert st.exists("kv/page/a.bin")
    assert list(st.keys()) == ["kv/page/a.bin"]
    assert st.used_bytes() == 5


def test_get_missing_raises_keyerror(tmp_path):
    st = SharedTier(tmp_path / "dom")
    with pytest.raises(KeyError):
        st.get("nope")
    assert not st.exists("nope")
    st.delete("nope")          # idempotent


def test_overwrite_replaces_and_accounts(tmp_path):
    st = SharedTier(tmp_path / "dom")
    st.put("k", b"x" * 100)
    st.put("k", b"y" * 40)
    assert st.get("k") == b"y" * 40
    assert st.used_bytes() == 40


def test_capacity_enforced(tmp_path):
    st = SharedTier(tmp_path / "dom", capacity_bytes=100)
    st.put("a", b"x" * 60)
    with pytest.raises(CapacityError):
        st.put("b", b"y" * 60)
    # overwrite frees the old size first
    st.put("a", b"z" * 90)
    assert st.get("a") == b"z" * 90


def test_put_stream_joins(tmp_path):
    st = SharedTier(tmp_path / "dom")
    st.put_stream("s", [b"ab", b"cd", b"ef"])
    assert st.get("s") == b"abcdef"


def test_key_sanitization(tmp_path):
    st = SharedTier(tmp_path / "dom")
    st.put("a/../b", b"x")     # traversal components dropped, not honored
    assert st.get("a/b") == b"x"
    with pytest.raises(KeyError):
        st.put("..", b"x")


def test_no_torn_reads_visible(tmp_path):
    # a .tmp left behind by a "crashed" writer is invisible to readers
    st = SharedTier(tmp_path / "dom")
    st.put("real", b"data")
    (st._objs / "ghost.123.0.tmp").write_bytes(b"partial")
    assert list(st.keys()) == ["real"]
    assert not st.exists("ghost")


def test_two_handles_same_root_share_objects(tmp_path):
    a = SharedTier(tmp_path / "dom")
    b = SharedTier(tmp_path / "dom")
    a.put("k", b"from-a")
    assert b.get("k") == b"from-a"
    assert b.used_bytes() == 6


def test_delete_refcounts_by_publisher(tmp_path):
    # same pid publishing through two handles is ONE publisher; the
    # cross-pid flavor is exercised by the race tests below
    a = SharedTier(tmp_path / "dom")
    a.put("k", b"v")
    a.delete("k")
    assert not a.exists("k")
    assert a.manifest() == {}


def test_nonpublisher_delete_is_noop_on_object(tmp_path):
    a = SharedTier(tmp_path / "dom")
    a.put("k", b"v")
    b = SharedTier(tmp_path / "dom")
    # b never published k; manifest says pid(a)==pid(b) here (same
    # process), so this unit test only pins the entry-missing path:
    b.delete("unrelated")
    assert a.get("k") == b"v"


def test_accepts_spill_flag(tmp_path):
    assert SharedTier(tmp_path / "dom").accepts_spill is True


def test_spec_is_shared_class(tmp_path):
    assert SharedTier(tmp_path / "dom").spec.shared is True


# --------------------------------------------------------------------------- #
# as a TierStack level
# --------------------------------------------------------------------------- #

def test_stack_reads_through_to_shared_level(tmp_path):
    from repro.serve.kvpage import KVPager

    dom = tmp_path / "dom"
    a = KVPager.for_fleet(SharedTier(dom), fast_bytes=1 << 20)
    b = KVPager.for_fleet(SharedTier(dom), fast_bytes=1 << 20)
    a.stack.put_at("shared", "kv/prefix/x.bin", b"page-bytes")
    # b's fast tier misses, the shared level hits
    assert b.stack.get("kv/prefix/x.bin") == b"page-bytes"
    st = b.stack.stats()
    assert st["hits_shared"] == 1 and st["misses_hbm"] == 1
    assert a.stack.stats()["direct_puts"] == 1
    a.close()
    b.close()


def test_put_at_unknown_level_raises(tmp_path):
    from repro.serve.kvpage import KVPager

    p = KVPager.for_fleet(SharedTier(tmp_path / "dom"), fast_bytes=1 << 20)
    with pytest.raises(KeyError):
        p.stack.put_at("nvme-of", "k", b"x")
    p.close()


# --------------------------------------------------------------------------- #
# real multi-process races
# --------------------------------------------------------------------------- #

def _race_writer(root, worker, n_keys, barrier):
    st = SharedTier(root)
    barrier.wait()
    for r in range(3):
        for i in range(n_keys):
            key = f"kv/obj{i:03d}.bin"
            try:
                st.put(key, _blob(key))
            except CapacityError:
                pass
            if (i + worker + r) % 4 == 0:
                st.delete(key)


def _race_reader(root, n_keys, barrier, errq):
    st = SharedTier(root)
    barrier.wait()
    for _ in range(4):
        for i in range(n_keys):
            key = f"kv/obj{i:03d}.bin"
            try:
                data = st.get(key)
            except KeyError:
                continue              # deleted between exists and get: legal
            if data != _blob(key):
                errq.put(f"torn read on {key}: {len(data)} bytes")


@pytest.mark.parametrize("n_writers", [2, 3])
def test_concurrent_put_get_delete_across_processes(tmp_path, n_writers):
    """Writers race put/delete while readers verify every successful get
    returns the complete expected content — the rename-commit claim."""
    ctx = mp.get_context("spawn")
    root, n_keys = tmp_path / "dom", 24
    SharedTier(root)               # create the domain up front
    barrier = ctx.Barrier(n_writers + 1)
    errq = ctx.Queue()
    procs = [ctx.Process(target=_race_writer,
                         args=(root, w, n_keys, barrier))
             for w in range(n_writers)]
    procs.append(ctx.Process(target=_race_reader,
                             args=(root, n_keys, barrier, errq)))
    for p in procs:
        p.start()
    for p in procs:
        p.join(60)
        assert p.exitcode == 0
    assert errq.empty(), errq.get()
    # manifest consistent with the object directory after the dust settles
    st = SharedTier(root)
    manifest = st.manifest()
    assert sorted(manifest) == list(st.keys())
    for key, entry in manifest.items():
        assert entry["size"] == len(st.get(key))


def _pub_then_wait_delete(root, key, started, release):
    st = SharedTier(root)
    st.put(key, _blob(key))
    started.set()
    release.wait(30)
    st.delete(key)


def test_publisher_refcount_across_processes(tmp_path):
    """A publishes, B publishes; A's delete must NOT unlink (B still
    holds it), B's delete must."""
    ctx = mp.get_context("spawn")
    root, key = tmp_path / "dom", "kv/sharedpage.bin"
    st = SharedTier(root)
    a_started, a_release = ctx.Event(), ctx.Event()
    pa = ctx.Process(target=_pub_then_wait_delete,
                     args=(root, key, a_started, a_release))
    pa.start()
    assert a_started.wait(30)
    st.put(key, _blob(key))        # this process is the second publisher
    assert len(st.manifest()[key]["pubs"]) == 2
    a_release.set()                # A deletes (unregisters itself)...
    pa.join(30)
    assert pa.exitcode == 0
    assert st.get(key) == _blob(key)   # ...object survives for us
    assert st.manifest()[key]["pubs"] == [__import__("os").getpid()]
    st.delete(key)                 # last publisher lets go
    assert not st.exists(key)
    assert key not in st.manifest()
