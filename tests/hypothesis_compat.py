"""Degrade property-based tests to skips when `hypothesis` is absent.

The container this repo targets does not guarantee hypothesis; importing
it unconditionally turns whole test modules into collection errors.  Test
modules import `given`/`settings`/`st` from here instead: with hypothesis
installed they are the real thing; without it, `@given(...)` replaces the
test with a skip and every other test in the module still runs.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Accepts any strategy-builder call chain at collection time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
