"""Dry-run machinery: collective parsing units + small-mesh compile smoke.

The full 16x16 / 2x16x16 sweeps run via ``python -m repro.launch.dryrun
--all [--multi-pod]`` (results in benchmarks/results/); here we verify the
machinery itself on a 2x2(x2) mesh in subprocesses (jax pins the device
count at first init, so each mesh size needs a fresh interpreter).
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.launch.dryrun import parse_collectives

REPO = Path(__file__).resolve().parent.parent


def run_sub(code: str, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_parse_collectives_accounting():
    hlo = """
  %ar = f32[128,64]{1,0} all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%add
  %ag = bf16[256]{0} all-gather(%y), replica_groups=[4,2]<=[8], dimensions={0}
  %rs = f32[32]{0} reduce-scatter(%z), replica_groups=[2,4]<=[8], to_apply=%add
  %cp = s32[16,16]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %tup = (f32[8]{0}, f32[8]{0}) all-reduce(%a, %b), replica_groups=[1,8]<=[8], to_apply=%add
"""
    got = parse_collectives(hlo)
    assert got["all-reduce"]["count"] == 2
    # f32[128,64]=32768B * 2*(4-1)/4 + tuple 2*32B * 2*(8-1)/8
    assert abs(got["all-reduce"]["bytes"] - (32768 * 1.5 + 64 * 1.75)) < 1
    assert got["all-gather"]["count"] == 1
    assert abs(got["all-gather"]["bytes"] - 512 * 0.5) < 1
    assert abs(got["reduce-scatter"]["bytes"] - 128 * 3) < 1
    assert got["collective-permute"]["bytes"] == 16 * 16 * 4


def test_parse_collectives_ignores_unrelated():
    assert parse_collectives("%f = f32[2] add(%a, %b)\n") == {}


@pytest.mark.slow
def test_small_mesh_train_cell_compiles():
    out = run_sub(
        "from repro.launch.dryrun import lower_cell\n"
        "from repro.launch.mesh import make_test_mesh\n"
        "import json\n"
        "rec = lower_cell('whisper-tiny', 'train_4k', make_test_mesh(), tp=2)\n"
        "print(json.dumps({'ok': rec['ok'], 'flops': rec['hlo_flops'],\n"
        "                  'coll': sum(v['bytes'] for v in rec['collectives'].values())}))\n"
    )
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["ok"] and rec["flops"] > 0 and rec["coll"] > 0


@pytest.mark.slow
def test_small_mesh_multipod_decode_compiles():
    out = run_sub(
        "from repro.launch.dryrun import lower_cell\n"
        "from repro.launch.mesh import make_test_mesh\n"
        "import json\n"
        "mesh = make_test_mesh(multi_pod=True)\n"
        "rec = lower_cell('whisper-tiny', 'decode_32k', mesh, tp=2, fast=True)\n"
        "print(json.dumps({'ok': rec['ok'], 'mesh': rec['mesh']}))\n"
    )
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["ok"] and rec["mesh"] == "2x2x2"


@pytest.mark.slow
def test_small_mesh_long500k_rwkv_compiles():
    out = run_sub(
        "from repro.launch.dryrun import lower_cell\n"
        "from repro.launch.mesh import make_test_mesh\n"
        "import json\n"
        "rec = lower_cell('rwkv6-3b', 'long_500k', make_test_mesh(), tp=2, fast=True)\n"
        "print(json.dumps({'ok': rec['ok']}))\n"
    )
    assert json.loads(out.strip().splitlines()[-1])["ok"]


@pytest.mark.slow
def test_offload_engine_on_split_mesh():
    out = run_sub(
        "import jax, numpy as np\n"
        "import jax.numpy as jnp\n"
        "from jax.sharding import PartitionSpec as P\n"
        "from repro.core.offload import split_mesh, OffloadEngine\n"
        "from repro.cluster.topology import Module\n"
        "mesh = jax.make_mesh((4, 2), ('data', 'model'))\n"
        "mods = split_mesh(mesh, 2, axis='data')\n"
        "eng = OffloadEngine(mods)\n"
        "x = jnp.arange(16.0).reshape(4, 4)\n"
        "y = eng.offload(lambda a: a * 2, Module.BOOSTER, x,\n"
        "                in_specs=[P('data', None)], out_specs=P('data', None))\n"
        "z = eng.gather(y, Module.CLUSTER, P())\n"
        "assert np.allclose(np.asarray(z), np.asarray(x) * 2)\n"
        "assert set(y.devices()) == set(mods[Module.BOOSTER].mesh.devices.flat)\n"
        "print('OK')\n"
    )
    assert "OK" in out


@pytest.mark.slow
def test_seq_parallel_matches_baseline():
    """Ulysses seq-parallel prefill == baseline forward (MLA + GQA)."""
    out = run_sub(
        "import dataclasses, jax, numpy as np\n"
        "import jax.numpy as jnp\n"
        "from repro.configs import get_config\n"
        "from repro.models.registry import get_model\n"
        "from repro.models import transformer as T\n"
        "mesh = jax.make_mesh((2, 2), ('data', 'model'))\n"
        "for arch in ['minicpm3-4b', 'starcoder2-7b']:\n"
        "    cfg = get_config(arch).reduced()\n"
        "    cfg = dataclasses.replace(cfg, tp=2, tie_embeddings=False)\n"
        "    cfg_sp = dataclasses.replace(cfg, seq_parallel=True)\n"
        "    params = T.init(jax.random.PRNGKey(0), cfg)\n"
        "    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,\n"
        "                              cfg.vocab_size, jnp.int32)\n"
        "    base, _ = T.forward(params, {'tokens': toks}, cfg, remat=False)\n"
        "    with mesh:\n"
        "        sp, _ = jax.jit(lambda p, b: T.forward(p, b, cfg_sp,\n"
        "                        remat=False, mesh=mesh))(params, {'tokens': toks})\n"
        "    a = np.asarray(base[..., :cfg.vocab_size], np.float32)\n"
        "    b = np.asarray(sp[..., :cfg.vocab_size], np.float32)\n"
        "    err = np.abs(a - b).max()\n"
        "    rel = err / max(np.abs(a).max(), 1e-6)\n"
        "    assert rel < 3e-2, (arch, err, rel)\n"
        "    print(arch, 'rel_err', rel)\n"
        "print('OK')\n"
    )
    assert "OK" in out


@pytest.mark.slow
def test_xor_all_reduce_butterfly():
    """The NAM-equivalent on-device parity: butterfly XOR over a mesh axis."""
    out = run_sub(
        "import jax, numpy as np\n"
        "import jax.numpy as jnp\n"
        "from jax.sharding import PartitionSpec as P\n"
        "from jax.experimental.shard_map import shard_map\n"
        "from repro.distributed.collectives import xor_all_reduce\n"
        "mesh = jax.make_mesh((8,), ('model',))\n"
        "rng = np.random.default_rng(0)\n"
        "blocks = rng.integers(-2**31, 2**31, size=(8, 16, 128), dtype=np.int32)\n"
        "want = blocks[0].copy()\n"
        "for b in blocks[1:]:\n"
        "    want ^= b\n"
        "x = jnp.asarray(blocks.reshape(8 * 16, 128))\n"
        "f = shard_map(lambda v: xor_all_reduce(v, 'model'), mesh=mesh,\n"
        "              in_specs=P('model', None), out_specs=P('model', None),\n"
        "              check_rep=False)\n"
        "got = np.asarray(jax.jit(f)(x)).reshape(8, 16, 128)\n"
        "for i in range(8):\n"
        "    assert np.array_equal(got[i], want), i\n"
        "print('OK')\n"
    )
    assert "OK" in out
